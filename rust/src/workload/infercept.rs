//! Synthetic INFERCEPT-style datasets (DESIGN.md §2 substitution).
//!
//! The paper evaluates on (1) a *single-API* subset of the INFERCEPT
//! dataset and (2) the *full* (multi-API) INFERCEPT dataset, which mixes
//! six augmentation classes (math, QA, virtual environment, chatbot, image
//! generation, TTS). The real artifact is not redistributable; these
//! generators reproduce its published Table 2 statistics: per-class API
//! durations and calls-per-request counts drawn from truncated normals.

use crate::core::request::{ApiCallSpec, ApiType, RequestSpec};
use crate::core::types::{Micros, RequestId, Tokens};
use crate::predictor::api_stats::{stats_for, INFERCEPT_CLASSES};
use crate::util::Rng;
use crate::workload::{ArrivalProcess, Trace};

/// Output-length profile shared by both INFERCEPT variants. Not in
/// Table 2; chosen to give paper-like context sizes (prompts of ~10^2
/// tokens, outputs of ~10^2 tokens).
const PROMPT_MEAN: f64 = 128.0;
const PROMPT_STD: f64 = 64.0;
const PRE_API_MEAN: f64 = 80.0;
const PRE_API_STD: f64 = 40.0;
const FINAL_MEAN: f64 = 120.0;
const FINAL_STD: f64 = 60.0;

fn sample_tokens(rng: &mut Rng, mean: f64, std: f64, min: f64) -> Tokens {
    Tokens(rng.truncated_normal(mean, std, min).round() as u64)
}

fn sample_call(rng: &mut Rng, api: ApiType, decode_mean: f64,
               decode_std: f64) -> ApiCallSpec {
    let st = stats_for(api);
    let duration = rng.truncated_normal(st.duration_secs.0,
                                        st.duration_secs.1, 1e-6);
    let response = rng.truncated_normal(st.response_tokens.0,
                                        st.response_tokens.1, 0.0);
    ApiCallSpec {
        decode_before: sample_tokens(rng, decode_mean, decode_std, 1.0),
        api_type: api,
        duration: Micros::from_secs_f64(duration),
        response_tokens: Tokens(response.round() as u64),
    }
}

/// Single-API subset: every request has exactly one API call, class drawn
/// uniformly over the six augmentation types.
pub fn single_api_dataset(n: usize, rate: f64, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let arrivals = ArrivalProcess::Poisson { rate }.sample(n, &mut rng);
    let requests = arrivals
        .into_iter()
        .enumerate()
        .map(|(i, arrival)| {
            let api = *rng.choice(&INFERCEPT_CLASSES);
            RequestSpec {
                id: RequestId(i as u64),
                arrival,
                prompt: String::new(),
                prompt_tokens: sample_tokens(&mut rng, PROMPT_MEAN,
                                             PROMPT_STD, 8.0),
                api_calls: vec![sample_call(&mut rng, api, PRE_API_MEAN,
                                            PRE_API_STD)],
                final_decode: sample_tokens(&mut rng, FINAL_MEAN,
                                            FINAL_STD, 1.0),
            }
        })
        .collect();
    Trace::new("infercept-single-api", rate, requests)
}

/// Full (multi-API) dataset: each request draws one augmentation class and
/// a per-class number of calls from Table 2's calls-per-request normal.
pub fn multi_api_dataset(n: usize, rate: f64, seed: u64) -> Trace {
    let mut rng = Rng::new(seed ^ 0x5EED_0002);
    let arrivals = ArrivalProcess::Poisson { rate }.sample(n, &mut rng);
    let requests = arrivals
        .into_iter()
        .enumerate()
        .map(|(i, arrival)| {
            let api = *rng.choice(&INFERCEPT_CLASSES);
            let st = stats_for(api);
            let n_calls = rng
                .truncated_normal(st.calls_per_request.0,
                                  st.calls_per_request.1, 1.0)
                .round() as usize;
            // Inter-API decode segments are shorter than single-API ones
            // (the same total output is split across segments).
            let seg_mean = (PRE_API_MEAN / (n_calls as f64).sqrt()).max(4.0);
            let api_calls = (0..n_calls)
                .map(|_| sample_call(&mut rng, api, seg_mean, seg_mean / 2.0))
                .collect();
            RequestSpec {
                id: RequestId(i as u64),
                arrival,
                prompt: String::new(),
                prompt_tokens: sample_tokens(&mut rng, PROMPT_MEAN,
                                             PROMPT_STD, 8.0),
                api_calls,
                final_decode: sample_tokens(&mut rng, FINAL_MEAN,
                                            FINAL_STD, 1.0),
            }
        })
        .collect();
    Trace::new("infercept-multi-api", rate, requests)
}

/// Fig 2's comparison dataset: the single-API subset with API calls
/// stripped (the "without API calls" variant).
pub fn strip_api_calls(trace: &Trace) -> Trace {
    let requests = trace
        .requests
        .iter()
        .map(|r| {
            let decode_total = r.total_decode();
            RequestSpec {
                id: r.id,
                arrival: r.arrival,
                prompt: r.prompt.clone(),
                prompt_tokens: r.prompt_tokens,
                api_calls: vec![],
                final_decode: decode_total,
            }
        })
        .collect();
    Trace::new(&format!("{}-no-api", trace.name), trace.rate, requests)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_api_shape() {
        let t = single_api_dataset(200, 3.0, 1);
        assert_eq!(t.len(), 200);
        for r in &t.requests {
            assert_eq!(r.api_calls.len(), 1);
            assert!(r.prompt_tokens.0 >= 8);
            assert!(r.final_decode.0 >= 1);
            assert!(r.api_calls[0].decode_before.0 >= 1);
        }
    }

    #[test]
    fn single_api_durations_match_table2() {
        let t = single_api_dataset(4000, 3.0, 2);
        for (label, summary) in t.api_class_stats() {
            let expected = match label.as_str() {
                "math" => 9e-5,
                "qa" => 0.69,
                "ve" => 0.09,
                "chatbot" => 28.6,
                "image" => 20.03,
                "tts" => 17.24,
                other => panic!("unexpected class {other}"),
            };
            let rel = (summary.duration_mean - expected).abs()
                / expected.max(1e-9);
            // Truncation at 0 biases the heavy-std classes slightly up.
            assert!(rel < 0.15,
                    "{label}: mean {} vs expected {expected}",
                    summary.duration_mean);
        }
    }

    #[test]
    fn multi_api_calls_per_request_match_table2() {
        let t = multi_api_dataset(3000, 3.0, 3);
        for (label, summary) in t.api_class_stats() {
            let expected = match label.as_str() {
                "math" => 3.75,
                "qa" => 2.52,
                "ve" => 28.18,
                "chatbot" => 4.45,
                "image" => 6.91,
                "tts" => 6.91,
                other => panic!("unexpected class {other}"),
            };
            let rel = (summary.calls_mean - expected).abs() / expected;
            assert!(rel < 0.25,
                    "{label}: calls {} vs expected {expected}",
                    summary.calls_mean);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = multi_api_dataset(50, 3.0, 9);
        let b = multi_api_dataset(50, 3.0, 9);
        assert_eq!(a.requests, b.requests);
        let c = multi_api_dataset(50, 3.0, 10);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn strip_preserves_total_decode() {
        let t = multi_api_dataset(50, 3.0, 4);
        let stripped = strip_api_calls(&t);
        for (orig, bare) in t.requests.iter().zip(&stripped.requests) {
            assert!(bare.api_calls.is_empty());
            assert_eq!(bare.total_decode(), orig.total_decode());
            assert_eq!(bare.total_api_time(), Micros::ZERO);
        }
    }
}
