//! `lamps-lint` — the project's static-analysis gate (see
//! `lamps::lint` for the rules). Scans `rust/src` by default, or the
//! tree given as the first argument (CI points it at the fixture
//! corpus to prove the rules still bite).
//!
//! Exit status: 0 when clean, 1 when any violation is reported, 2 on
//! I/O trouble.

use std::path::PathBuf;
use std::process::ExitCode;

use lamps::lint;

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src"))
        });
    let violations = match lint::scan_tree(&root) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("lamps-lint: cannot scan {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    if violations.is_empty() {
        println!("lamps-lint: {} clean", root.display());
        return ExitCode::SUCCESS;
    }
    for v in &violations {
        println!("{v}");
    }
    println!("lamps-lint: {} violation(s) in {}", violations.len(),
             root.display());
    ExitCode::from(1)
}
