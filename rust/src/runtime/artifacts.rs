//! Artifact discovery: `artifacts/meta.json` describes the exported HLO
//! modules (shapes, model dims, tokenizer contract) — the schema written
//! by `python/compile/aot.py`. Parsed with the in-tree JSON module.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{self, Value};

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub vocab_size: u32,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_model: usize,
    pub max_seq: usize,
    pub batch: usize,
    pub kv_bytes_per_token: u64,
    pub prefill_hlo: String,
    pub decode_hlo: String,
    pub eos_id: i32,
}

impl ModelMeta {
    fn from_value(v: &Value) -> Result<ModelMeta> {
        Ok(ModelMeta {
            name: v.str_field("name")?,
            vocab_size: v.u64_field("vocab_size")? as u32,
            n_layers: v.u64_field("n_layers")? as usize,
            n_heads: v.u64_field("n_heads")? as usize,
            head_dim: v.u64_field("head_dim")? as usize,
            d_model: v.u64_field("d_model")? as usize,
            max_seq: v.u64_field("max_seq")? as usize,
            batch: v.u64_field("batch")? as usize,
            kv_bytes_per_token: v.u64_field("kv_bytes_per_token")?,
            prefill_hlo: v.str_field("prefill_hlo")?,
            decode_hlo: v.str_field("decode_hlo")?,
            eos_id: v.u64_field("eos_id")? as i32,
        })
    }

    /// Elements of one KV tensor: (L, B, S, H, D).
    pub fn kv_elements(&self) -> usize {
        self.n_layers * self.batch * self.max_seq * self.n_heads
            * self.head_dim
    }

    pub fn kv_dims(&self) -> [i64; 5] {
        [self.n_layers as i64, self.batch as i64, self.max_seq as i64,
         self.n_heads as i64, self.head_dim as i64]
    }
}

#[derive(Debug, Clone)]
pub struct PredictorMeta {
    pub predictor_hlo: String,
    pub max_prompt: usize,
    pub num_bins: u32,
    pub bin_width: u32,
    pub vocab_size: u32,
    pub acc5: f64,
    pub acc15: f64,
    pub mae_words: f64,
}

impl PredictorMeta {
    fn from_value(v: &Value) -> Result<PredictorMeta> {
        Ok(PredictorMeta {
            predictor_hlo: v.str_field("predictor_hlo")?,
            max_prompt: v.u64_field("max_prompt")? as usize,
            num_bins: v.u64_field("num_bins")? as u32,
            bin_width: v.u64_field("bin_width")? as u32,
            vocab_size: v.u64_field("vocab_size")? as u32,
            acc5: v.f64_field("acc5")?,
            acc15: v.f64_field("acc15")?,
            mae_words: v.f64_field("mae_words")?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct TokenizerMeta {
    pub vocab_size: u32,
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
    pub reserved: u32,
    pub scheme: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub format: String,
    pub models: HashMap<String, ModelMeta>,
    pub predictor: PredictorMeta,
    pub tokenizer: TokenizerMeta,
    pub dir: PathBuf,
}

impl ArtifactMeta {
    pub fn parse(text: &str, dir: PathBuf) -> Result<ArtifactMeta> {
        let v = json::parse(text).context("parsing meta.json")?;
        let mut models = HashMap::new();
        for (name, mv) in v
            .field("models")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("models not an object"))?
        {
            models.insert(name.clone(), ModelMeta::from_value(mv)?);
        }
        let tok = v.field("tokenizer")?;
        Ok(ArtifactMeta {
            format: v.str_field("format")?,
            models,
            predictor: PredictorMeta::from_value(v.field("predictor")?)?,
            tokenizer: TokenizerMeta {
                vocab_size: tok.u64_field("vocab_size")? as u32,
                pad_id: tok.u64_field("pad_id")? as i32,
                bos_id: tok.u64_field("bos_id")? as i32,
                eos_id: tok.u64_field("eos_id")? as i32,
                reserved: tok.u64_field("reserved")? as u32,
                scheme: tok.str_field("scheme")?,
            },
            dir,
        })
    }

    /// Load `<dir>/meta.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactMeta> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {} — run `make artifacts` first",
                    path.display())
        })?;
        ArtifactMeta::parse(&text, dir)
    }

    /// Default artifact directory: `$LAMPS_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<ArtifactMeta> {
        let dir = std::env::var("LAMPS_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        ArtifactMeta::load(dir)
    }

    pub fn hlo_path(&self, file: &str) -> String {
        self.dir.join(file).to_string_lossy().into_owned()
    }

    pub fn model(&self, preset: &str) -> Result<&ModelMeta> {
        self.models.get(preset).ok_or_else(|| {
            anyhow::anyhow!("no model preset '{preset}' in meta.json \
                             (available: {:?})",
                            self.models.keys().collect::<Vec<_>>())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_schema() {
        let json_text = r#"{
            "format": "hlo-text",
            "models": {
                "gptj-tiny": {
                    "name": "gptj-tiny", "vocab_size": 512,
                    "n_layers": 4, "n_heads": 4, "head_dim": 32,
                    "d_model": 128, "max_seq": 128, "batch": 4,
                    "kv_bytes_per_token": 4096,
                    "prefill_hlo": "gptj-tiny_prefill.hlo.txt",
                    "decode_hlo": "gptj-tiny_decode.hlo.txt",
                    "eos_id": 2
                }
            },
            "predictor": {
                "predictor_hlo": "predictor.hlo.txt",
                "max_prompt": 64, "num_bins": 50, "bin_width": 10,
                "vocab_size": 512, "acc5": 0.6, "acc15": 0.9,
                "mae_words": 5.0
            },
            "tokenizer": {
                "vocab_size": 512, "pad_id": 0, "bos_id": 1, "eos_id": 2,
                "reserved": 8, "scheme": "fnv1a64-word-hash"
            }
        }"#;
        let meta =
            ArtifactMeta::parse(json_text, PathBuf::from("/tmp")).unwrap();
        let m = meta.model("gptj-tiny").unwrap();
        assert_eq!(m.kv_elements(), 4 * 4 * 128 * 4 * 32);
        assert_eq!(m.kv_dims(), [4, 4, 128, 4, 32]);
        assert!(meta.model("missing").is_err());
        assert_eq!(meta.predictor.num_bins, 50);
        assert_eq!(meta.tokenizer.scheme, "fnv1a64-word-hash");
    }
}
