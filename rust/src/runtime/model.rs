//! Typed wrappers over the exported executables: the TinyGPT serving pair
//! (prefill + decode) and the length-predictor classifier.
//!
//! KV layout is `(L, B, S, H, D)` f32, matching `python/compile/aot.py`'s
//! lowering. Helpers here slice/merge per-slot KV so the backend can pack
//! independent requests into the fixed-shape batch.

use anyhow::Result;

use crate::runtime::artifacts::{ArtifactMeta, ModelMeta, PredictorMeta};
use crate::runtime::{literal_i32, Executable, RuntimeClient};
use crate::util::tokenizer;

/// Prefill + decode executables for one model preset.
pub struct ModelRuntime {
    pub meta: ModelMeta,
    prefill: Executable,
    decode: Executable,
}

/// Outputs of a prefill/decode call: next tokens per slot + full-batch KV.
pub struct StepResult {
    pub next_tokens: Vec<i32>,
    /// (L, B, S, H, D) flattened.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl ModelRuntime {
    pub fn load(client: &RuntimeClient, artifacts: &ArtifactMeta,
                preset: &str) -> Result<ModelRuntime> {
        let meta = artifacts.model(preset)?.clone();
        let prefill =
            client.load_hlo_text(&artifacts.hlo_path(&meta.prefill_hlo))?;
        let decode =
            client.load_hlo_text(&artifacts.hlo_path(&meta.decode_hlo))?;
        Ok(ModelRuntime {
            meta,
            prefill,
            decode,
        })
    }

    /// Elements in one slot's KV slice per layer: S * H * D.
    pub fn slot_stride(&self) -> usize {
        self.meta.max_seq * self.meta.n_heads * self.meta.head_dim
    }

    /// Run prefill: `tokens` is (B, S) row-major, `lengths` (B,).
    pub fn run_prefill(&self, tokens: &[i32], lengths: &[i32])
                       -> Result<StepResult> {
        let b = self.meta.batch as i64;
        let s = self.meta.max_seq as i64;
        assert_eq!(tokens.len(), (b * s) as usize);
        assert_eq!(lengths.len(), b as usize);
        let args = [
            literal_i32(tokens, &[b, s])?,
            literal_i32(lengths, &[b])?,
        ];
        let out = self.prefill.run(&args)?;
        self.unpack(out)
    }

    /// Run one decode step: `token`/`pos` are (B,), `k`/`v` the full
    /// (L,B,S,H,D) caches.
    pub fn run_decode(&self, token: &[i32], pos: &[i32], k: &[f32],
                      v: &[f32]) -> Result<StepResult> {
        let b = self.meta.batch as i64;
        let kv_dims: Vec<i64> = self.meta.kv_dims().to_vec();
        assert_eq!(k.len(), self.meta.kv_elements());
        let args = [
            literal_i32(token, &[b])?,
            literal_i32(pos, &[b])?,
            crate::runtime::literal_f32(k, &kv_dims)?,
            crate::runtime::literal_f32(v, &kv_dims)?,
        ];
        let out = self.decode.run(&args)?;
        self.unpack(out)
    }

    fn unpack(&self, out: xla::Literal) -> Result<StepResult> {
        let (next, k, v) = out.to_tuple3()?;
        Ok(StepResult {
            next_tokens: next.to_vec::<i32>()?,
            k: k.to_vec::<f32>()?,
            v: v.to_vec::<f32>()?,
        })
    }

    /// Copy slot `b`'s per-layer KV slices out of a full-batch tensor into
    /// a compact (L, S, H, D) buffer.
    pub fn extract_slot(&self, full: &[f32], slot: usize) -> Vec<f32> {
        let stride = self.slot_stride();
        let b_count = self.meta.batch;
        let mut out = Vec::with_capacity(self.meta.n_layers * stride);
        for layer in 0..self.meta.n_layers {
            let base = (layer * b_count + slot) * stride;
            out.extend_from_slice(&full[base..base + stride]);
        }
        out
    }

    /// Write a compact (L, S, H, D) buffer into slot `b` of a full-batch
    /// tensor.
    pub fn insert_slot(&self, full: &mut [f32], slot: usize,
                       compact: &[f32]) {
        let stride = self.slot_stride();
        let b_count = self.meta.batch;
        for layer in 0..self.meta.n_layers {
            let base = (layer * b_count + slot) * stride;
            full[base..base + stride]
                .copy_from_slice(&compact[layer * stride
                    ..(layer + 1) * stride]);
        }
    }

    pub fn zero_kv(&self) -> Vec<f32> {
        vec![0.0; self.meta.kv_elements()]
    }
}

/// The AOT-compiled length predictor (OPT-125M stand-in).
pub struct PredictorRuntime {
    pub meta: PredictorMeta,
    exe: Executable,
}

impl PredictorRuntime {
    pub fn load(client: &RuntimeClient, artifacts: &ArtifactMeta)
                -> Result<PredictorRuntime> {
        let exe = client
            .load_hlo_text(&artifacts.hlo_path(
                &artifacts.predictor.predictor_hlo))?;
        Ok(PredictorRuntime {
            meta: artifacts.predictor.clone(),
            exe,
        })
    }

    /// Predict the output-length bin for a prompt.
    pub fn predict_bin(&self, prompt: &str) -> Result<u32> {
        let ids = tokenizer::encode(prompt, self.meta.max_prompt);
        let lit = literal_i32(&ids, &[1, self.meta.max_prompt as i64])?;
        let out = self.exe.run(&[lit])?;
        let bin = out.to_tuple1()?.to_vec::<i32>()?[0];
        Ok(bin.clamp(0, self.meta.num_bins as i32 - 1) as u32)
    }

    /// Bin -> predicted length in tokens (bin midpoint).
    pub fn bin_to_tokens(&self, bin: u32) -> u64 {
        (bin as u64) * self.meta.bin_width as u64
            + (self.meta.bin_width as u64) / 2
    }
}
