//! PJRT runtime: loads the AOT-compiled HLO **text** artifacts produced by
//! `python/compile/aot.py` and executes them via the `xla` crate's PJRT
//! CPU client. This is the only place the Rust side touches XLA; Python
//! never runs on the request path.
//!
//! Interchange is HLO text because jax >= 0.5 serializes HloModuleProtos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md).

pub mod artifacts;
pub mod model;

pub use artifacts::{ArtifactMeta, ModelMeta, PredictorMeta};
pub use model::{ModelRuntime, PredictorRuntime};

use std::sync::Arc;

use anyhow::{Context, Result};

/// Shared PJRT CPU client + executable loader.
pub struct RuntimeClient {
    client: Arc<xla::PjRtClient>,
}

impl RuntimeClient {
    pub fn cpu() -> Result<RuntimeClient> {
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RuntimeClient {
            client: Arc::new(client),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load_hlo_text(&self, path: &str) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text at {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path}"))?;
        Ok(Executable {
            exe,
            path: path.to_string(),
        })
    }
}

/// A compiled, ready-to-run computation.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: String,
}

impl Executable {
    /// Execute with literal inputs; returns the first device's first
    /// output literal (our artifacts are lowered with `return_tuple=True`,
    /// so this is a tuple literal — decompose with `to_tupleN`).
    pub fn run(&self, args: &[xla::Literal]) -> Result<xla::Literal> {
        let outs = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {}", self.path))?;
        Ok(outs[0][0]
            .to_literal_sync()
            .context("fetching result literal")?)
    }
}

/// i32 helper: build a literal of the given shape from a slice.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// f32 helper.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}
