//! System configuration: scheduler choice, handling policy, memory budget,
//! and the simulator's calibrated cost model.
//!
//! Baseline systems from the paper's evaluation are expressed as presets
//! over two orthogonal axes (see [`SystemConfig::preset`]):
//!
//! | Preset            | Scheduler   | Handling policy          |
//! |-------------------|-------------|--------------------------|
//! | `vllm`            | FCFS        | always Discard (vLLM treats an API call as termination + a new request) |
//! | `infercept`       | FCFS        | min-waste chosen *at API time* with true values |
//! | `lamps`           | memory-over-time rank | min-waste *predicted at admission* |
//! | `lamps-no-sched`  | FCFS        | min-waste predicted at admission (Fig 10 ablation) |
//! | `sjf`             | SJF (pre-API length) | min-waste predicted |
//! | `sjf-total`       | SJF (length + API)   | min-waste predicted |

use crate::core::request::HandlingStrategy;
use crate::core::types::{Micros, Tokens};

/// Request-ordering policy (paper §3.1 / §4.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// First-come first-served by request id (vLLM / INFERCEPT default).
    Fcfs,
    /// Shortest Job First by predicted *output length only* (Fig 3b).
    Sjf,
    /// SJF by total length = output + API duration-in-token-units (Fig 3c).
    SjfTotal,
    /// LAMPS: rank by predicted memory-over-time integral (Fig 3d, §4.3).
    Lamps,
}

impl SchedulerKind {
    pub fn label(&self) -> &'static str {
        match self {
            SchedulerKind::Fcfs => "fcfs",
            SchedulerKind::Sjf => "sjf",
            SchedulerKind::SjfTotal => "sjf-total",
            SchedulerKind::Lamps => "lamps",
        }
    }
}

/// How handling strategies are assigned to API calls.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HandlingPolicy {
    /// Fixed strategy for every call (vLLM ≙ `Forced(Discard)`; Fig 2 uses
    /// `Forced(Preserve)` / `Forced(Discard)`).
    Forced(HandlingStrategy),
    /// INFERCEPT: evaluate waste equations (1)-(3) with *true* values when
    /// the request reaches the API.
    MinWasteAtApi,
    /// LAMPS: evaluate waste equations with *predicted* values at admission,
    /// before the request first runs (§4.2).
    MinWastePredicted,
}

/// Analytic cost model for the simulated backend, calibrated against PJRT
/// measurements of the tiny model and scaled to paper-like magnitudes
/// (EXPERIMENTS.md §Calibration).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Fixed cost of one decode iteration (kernel launch, sampling, ...).
    pub decode_base: Micros,
    /// Additional decode cost per context token in the batch (attention is
    /// memory-bound: time scales with the KV tokens read).
    pub decode_per_ctx_token_us: f64,
    /// Prefill / recompute cost per context token materialized.
    pub prefill_per_token_us: f64,
    /// Fixed latency of one swap transfer (PCIe round-trip + kernel
    /// sync). Without this term eqn (3) would strictly dominate eqn (2) —
    /// both scale identically in C_other — and Discard would never win.
    pub swap_base_us: f64,
    /// Cost per token for one direction of a CPU<->GPU swap.
    pub swap_per_token_us: f64,
    /// Scheduling overhead charged per *re-scored* request per iteration
    /// (motivates the selective score-update optimization, §4.3).
    pub rank_overhead_per_request_us: f64,
}

impl CostModel {
    /// Paper-scale defaults: ~10 ms base iteration + 1 us per KV token
    /// (≈30 ms at 20k ctx tokens, A100-like), 100 us/token prefill,
    /// 30 us/token swap (≈0.9 MB/token over ~32 GB/s PCIe).
    pub fn paper_scale() -> CostModel {
        CostModel {
            decode_base: Micros(10_000),
            decode_per_ctx_token_us: 1.0,
            prefill_per_token_us: 100.0,
            swap_base_us: 1_000.0,
            swap_per_token_us: 30.0,
            rank_overhead_per_request_us: 0.0,
        }
    }

    /// Unit-token mode: 1 decode iteration = 1 s, recompute 1 s/token,
    /// free swaps — the semantics of the paper's Fig. 3 worked example.
    pub fn unit() -> CostModel {
        CostModel {
            decode_base: Micros(1_000_000),
            decode_per_ctx_token_us: 0.0,
            prefill_per_token_us: 1_000_000.0,
            swap_base_us: 0.0,
            swap_per_token_us: 0.0,
            rank_overhead_per_request_us: 0.0,
        }
    }

    pub fn decode_iter_time(&self, batch_ctx: Tokens) -> Micros {
        self.decode_base
            + Micros((self.decode_per_ctx_token_us * batch_ctx.0 as f64)
                as u64)
    }

    pub fn prefill_time(&self, ctx: Tokens) -> Micros {
        Micros((self.prefill_per_token_us * ctx.0 as f64) as u64)
    }

    /// One direction (out or in) of a swap. Eqn (3) charges one of
    /// these per direction: 2x with the cache off; with the prefix
    /// cache on, the inbound leg covers only the non-resident tail
    /// (see `coordinator::handling::waste_swap`).
    pub fn swap_time(&self, ctx: Tokens) -> Micros {
        if ctx == Tokens::ZERO {
            return Micros::ZERO;
        }
        Micros((self.swap_base_us
            + self.swap_per_token_us * ctx.0 as f64) as u64)
    }
}

/// Cross-replica placement policy of the
/// [`ReplicaSet`](crate::cluster::ReplicaSet): which replica an arriving
/// request is dispatched to. Once placed, a request never migrates — its
/// KV state, swap traffic, and API returns all stay on the owning
/// replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementKind {
    /// Least total outstanding memory-over-time: the LAMPS rank integral
    /// (§4.3) summed over a replica's live requests steers placement the
    /// same way it steers ordering.
    MemoryOverTime,
    /// Memory-over-time plus prefix affinity: the arrival's own fresh
    /// rank integral — including its *prefill leg*, discounted by the
    /// leading prompt blocks already resident in a replica's prefix
    /// cache per the fleet [`SharedPrefixIndex`] — is added to each
    /// replica's outstanding load, so shared-prefix requests steer
    /// toward the replica that already holds their prefix (Preble-style
    /// distributed prefix-sharing-aware placement, expressed through
    /// the existing integral rather than a bolted-on heuristic).
    /// Without `--shared-prefix` the discount is zero everywhere and
    /// only the per-replica profiled inputs differentiate it from
    /// `MemoryOverTime`.
    ///
    /// [`SharedPrefixIndex`]: crate::cluster::SharedPrefixIndex
    PrefixAffinity,
    /// Fewest live (unfinished) requests.
    LeastLoaded,
    /// Rotate through replicas in arrival order.
    RoundRobin,
}

impl PlacementKind {
    pub fn label(&self) -> &'static str {
        match self {
            PlacementKind::MemoryOverTime => "memory-over-time",
            PlacementKind::PrefixAffinity => "prefix-affinity",
            PlacementKind::LeastLoaded => "least-loaded",
            PlacementKind::RoundRobin => "round-robin",
        }
    }

    /// Parse a CLI name (`--placement`).
    pub fn parse(name: &str) -> Option<PlacementKind> {
        Some(match name {
            "memory-over-time" | "mot" => PlacementKind::MemoryOverTime,
            "prefix-affinity" | "affinity" => {
                PlacementKind::PrefixAffinity
            }
            "least-loaded" => PlacementKind::LeastLoaded,
            "round-robin" => PlacementKind::RoundRobin,
            _ => return None,
        })
    }
}

/// Where API-call returns come from (`--api-source`): the substrate
/// behind the engine's [`ApiExecutor`](crate::engine::api_executor).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ApiSourceKind {
    /// The call's true duration is known up front (sampled by the
    /// workload generator and carried in the spec); returns fire from
    /// the executor's deadline heap. Byte-identical to the pre-seam
    /// engine — the default.
    #[default]
    Simulated,
    /// The *client* runs the tool: `ApiCallStarted` is pushed over the
    /// session event stream, the engine parks the request under the
    /// strategy chosen from the **predicted** duration, and the return
    /// fires only when a `tool_result` frame arrives
    /// (`SessionHandle::complete_api_call`). Return times are unknown
    /// to the scheduler — the predicted-vs-actual duration gap becomes
    /// observable end to end (`api_pred_err_hist` in the metrics).
    External,
}

impl ApiSourceKind {
    pub fn label(&self) -> &'static str {
        match self {
            ApiSourceKind::Simulated => "sim",
            ApiSourceKind::External => "external",
        }
    }

    /// Parse a CLI name (`--api-source`).
    pub fn parse(name: &str) -> Option<ApiSourceKind> {
        Some(match name {
            "sim" | "simulated" => ApiSourceKind::Simulated,
            "external" => ApiSourceKind::External,
            _ => return None,
        })
    }
}

/// How API-duration estimates are produced behind the
/// [`DurationModel`](crate::predictor::duration::DurationModel) seam
/// (`--api-pred` / `LAMPS_API_PRED`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ApiPredKind {
    /// Per-call estimates pass through untouched (the configured
    /// predictor's output, i.e. Table 2 class means for the classifier
    /// paths). Byte-identical to the pre-seam engine — the default.
    #[default]
    Static,
    /// Per-class online estimators (EWMA mean + windowed quantile
    /// sketch) learn from observed outcomes at the return sites and
    /// revise every subsequent estimate, blending toward a conservative
    /// class quantile when the observed relative error runs hot.
    Learned,
}

impl ApiPredKind {
    pub fn label(&self) -> &'static str {
        match self {
            ApiPredKind::Static => "static",
            ApiPredKind::Learned => "learned",
        }
    }

    /// Parse a CLI name (`--api-pred`).
    pub fn parse(name: &str) -> Option<ApiPredKind> {
        Some(match name {
            "static" => ApiPredKind::Static,
            "learned" => ApiPredKind::Learned,
            _ => return None,
        })
    }
}

/// Runtime invariant auditor (`--audit` / `LAMPS_AUDIT`): the
/// read-only [`audit`](crate::audit) pass re-checking block
/// conservation, prefix refcounts, shared-index subset, queue order,
/// clock monotonicity, and event causality after every engine/fleet
/// step. Observe-only by construction — the run report is
/// byte-identical whichever mode is set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AuditMode {
    /// On in debug builds (so every tier-1 test runs audited), off in
    /// release builds. The default.
    #[default]
    Auto,
    /// Always on (`--audit`, `LAMPS_AUDIT=on`).
    On,
    /// Always off (`LAMPS_AUDIT=off`), even in debug builds.
    Off,
}

impl AuditMode {
    /// Whether the auditor actually runs under this mode in this build.
    pub fn enabled(&self) -> bool {
        match self {
            AuditMode::Auto => cfg!(debug_assertions),
            AuditMode::On => true,
            AuditMode::Off => false,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            AuditMode::Auto => "auto",
            AuditMode::On => "on",
            AuditMode::Off => "off",
        }
    }

    /// Parse a CLI/env name (`LAMPS_AUDIT=on|off|auto`).
    pub fn parse(name: &str) -> Option<AuditMode> {
        Some(match name {
            "auto" => AuditMode::Auto,
            "on" => AuditMode::On,
            "off" => AuditMode::Off,
            _ => return None,
        })
    }
}

/// Which simulated network model carries cross-replica signals
/// (`--net-model`): the per-link delay distribution of the
/// [`cluster::net`](crate::cluster::net) subsystem. `Off` (the
/// default) keeps the fleet sequentially stepped with an exact
/// shared-prefix mirror and exact live placement probes —
/// byte-identical to the net-less fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NetModelKind {
    /// No modeled network: gossip, digests, and autoscale are all
    /// inert. The default.
    #[default]
    Off,
    /// Datacenter-local links: 50–200 µs per message.
    Lan,
    /// Cross-zone links: 2–10 ms per message.
    Wan,
}

impl NetModelKind {
    pub fn label(&self) -> &'static str {
        match self {
            NetModelKind::Off => "off",
            NetModelKind::Lan => "lan",
            NetModelKind::Wan => "wan",
        }
    }

    /// Parse a CLI name (`--net-model`).
    pub fn parse(name: &str) -> Option<NetModelKind> {
        Some(match name {
            "off" => NetModelKind::Off,
            "lan" => NetModelKind::Lan,
            "wan" => NetModelKind::Wan,
            _ => return None,
        })
    }

    /// Sampled one-way link delay bounds in microseconds (inclusive
    /// low, exclusive high). `None` for `Off`.
    pub fn delay_bounds_us(&self) -> Option<(u64, u64)> {
        match self {
            NetModelKind::Off => None,
            NetModelKind::Lan => Some((50, 200)),
            NetModelKind::Wan => Some((2_000, 10_000)),
        }
    }
}

/// Elastic replica-count bounds (`--autoscale MIN:MAX`): the fleet
/// starts with `min` active replicas and may warm up parked ones (with
/// prefix-cache pre-seeding from a sibling) or drain active ones back
/// to parked as the published load digests cross the watermarks. Only
/// meaningful with a modeled network (`--net-model` ≠ off).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AutoscaleConfig {
    /// Active replicas never drop below this.
    pub min: usize,
    /// Active replicas never exceed this (clamped to `--replicas`).
    pub max: usize,
}

impl AutoscaleConfig {
    /// Parse the CLI form `MIN:MAX`.
    pub fn parse(s: &str) -> Option<AutoscaleConfig> {
        let (lo, hi) = s.split_once(':')?;
        let min: usize = lo.trim().parse().ok()?;
        let max: usize = hi.trim().parse().ok()?;
        if min == 0 || min > max {
            return None;
        }
        Some(AutoscaleConfig { min, max })
    }
}

/// Modeled-network knobs (the [`cluster::net`](crate::cluster::net)
/// subsystem). With `model == Off` — the default — every other field
/// is inert and the fleet is byte-identical to the net-less one.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetConfig {
    /// Per-link delay distribution (`--net-model off|lan|wan`).
    pub model: NetModelKind,
    /// Gossip cadence (`--gossip-interval`, milliseconds on the CLI):
    /// how often each replica flushes its buffered `PrefixDelta`s and
    /// publishes a fresh load digest onto the network.
    pub gossip_interval: Micros,
    /// Staleness budget (`--staleness-budget`, milliseconds on the
    /// CLI): a load digest older than this is treated as unknown by
    /// the placement shortlist (an unknown replica is assumed idle —
    /// optimistic, and corrected by the live probe or the rescue
    /// re-validation).
    pub staleness_budget: Micros,
    /// Shortlist size (`--net-topk`): expensive live placement probes
    /// per arrival are capped at O(topk).
    pub topk: usize,
    /// Elastic replica bounds (`--autoscale MIN:MAX`); `None` keeps
    /// every replica active.
    pub autoscale: Option<AutoscaleConfig>,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            model: NetModelKind::Off,
            gossip_interval: Micros(5_000),
            staleness_budget: Micros(50_000),
            topk: 4,
            autoscale: None,
        }
    }
}

impl NetConfig {
    /// Is the modeled network in effect for a fleet of `replicas`?
    /// (A single engine has no cross-replica signals to model.)
    pub fn armed(&self, replicas: usize) -> bool {
        self.model != NetModelKind::Off && replicas > 1
    }
}

/// Which predictor feeds the scheduler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PredictorKind {
    /// True values from the workload spec (complete-information analyses,
    /// e.g. the Fig 3 example).
    Oracle,
    /// True values + Gaussian error ~ N(0, p * measured) per Fig 11.
    NoisyOracle { error_pct: f64 },
    /// The AOT-compiled OPT-125M stand-in, executed via PJRT (ToolBench).
    Pjrt,
}

/// Knobs of the token-budgeted batch composer
/// ([`crate::coordinator::batch`]). Defaults reproduce the legacy
/// engine behavior exactly: whole-prompt prefill, no per-iteration token
/// budget, synchronous (batch-stalling) swap transfers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ComposeConfig {
    /// Token budget for one composed iteration: each decode slot costs 1
    /// token, each prefill chunk its length. `None` = unbounded.
    /// Decode-ready requests are always scheduled even if the budget is
    /// smaller than the batch (decodes are latency-critical); the budget
    /// throttles prefill work.
    pub max_batch_tokens: Option<u64>,
    /// Maximum prefill tokens materialized per request per iteration;
    /// longer prompts and discard-recomputes are split into chunks so a
    /// single long recompute cannot stall co-batched decodes for its
    /// whole forward pass. `None` = whole-context (legacy behavior).
    pub prefill_chunk: Option<u64>,
    /// Run swap-out/swap-in as asynchronous background transfers tracked
    /// by [`crate::kv::TransferQueue`], overlapping decode instead of
    /// charging the whole batch synchronously (INFERCEPT eqn (3)'s stall
    /// term becomes overlap).
    pub async_swap: bool,
    /// `--prefill-chunk auto`: derive the chunk size from the profiled
    /// decode-iteration EMA each iteration (target: one chunk's forward
    /// time ≈ one decode iteration), instead of the static
    /// `prefill_chunk`. When set, `prefill_chunk` is ignored.
    pub auto_chunk: bool,
}

impl ComposeConfig {
    /// Preset used by the figure benches when chunking is enabled: a
    /// 512-token chunk bounds a recompute's per-iteration stall to
    /// ~51 ms at paper-scale prefill cost while leaving typical prompts
    /// (< 512 tokens) whole.
    pub fn chunked() -> ComposeConfig {
        ComposeConfig {
            max_batch_tokens: None,
            prefill_chunk: Some(512),
            async_swap: true,
            auto_chunk: false,
        }
    }

    pub fn is_chunked(&self) -> bool {
        self.prefill_chunk.is_some() || self.auto_chunk
    }
}

/// Knobs of the refcounted prefix cache in the KV
/// [`BlockManager`](crate::kv::BlockManager). Shared prompt prefixes
/// (system prompts, few-shot templates) and post-Discard recomputes are
/// deduplicated at full-block granularity: cache hits skip both the
/// physical block allocation and the prefill of the covered tokens.
/// Defaults are off-compatible: with `enabled = false` the block
/// manager, scheduler, and engine behave byte-identically to a build
/// without the feature.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PrefixCacheConfig {
    /// Master switch (`--prefix-cache` on the CLI). Off by default.
    pub enabled: bool,
    /// Maximum zero-ref cached blocks retained after frees, i.e. how
    /// much reclaimable "cold" prefix state may linger for future hits
    /// (`--prefix-cache-blocks N` on the CLI). `None` retains every
    /// freed shareable block; memory pressure still reclaims them (LRU)
    /// before any allocation reports OOM, so the cache never causes an
    /// admission failure.
    pub cache_blocks: Option<u64>,
}

impl PrefixCacheConfig {
    /// Enabled, unbounded retention (pressure-reclaimed only).
    pub fn on() -> PrefixCacheConfig {
        PrefixCacheConfig {
            enabled: true,
            cache_blocks: None,
        }
    }
}

/// Top-level system configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    pub scheduler: SchedulerKind,
    pub handling: HandlingPolicy,
    pub predictor: PredictorKind,
    /// KV memory budget in token slots (the paper caps each A100 at 40 GB;
    /// ≈0.9 MB/token for GPT-J 6B -> ~44k slots).
    pub memory_budget: Tokens,
    /// Maximum concurrently *decoding* requests (API-waiting requests do
    /// not occupy an execution slot).
    pub max_batch: usize,
    /// KV paging granularity in tokens (vLLM-style blocks).
    pub block_size: u64,
    /// Starvation promotion threshold in waited iterations; `None`
    /// disables prevention (Fig 9 sweeps this; paper default 100, §4.4).
    pub starvation_threshold: Option<u32>,
    /// Re-rank cached LAMPS scores every N iterations (§4.3; 10 for
    /// ToolBench, 1 elsewhere).
    pub score_update_interval: u64,
    /// Clairvoyant reservation admission: only admit a request if every
    /// in-flight Preserve/Swap API request can still resume at its
    /// (predicted) return time. This is what lets the pre-API part of a
    /// short request run "inside" another request's API call in the
    /// paper's Fig 3 walkthrough.
    pub admission_lookahead: bool,
    /// vLLM semantics: an API call terminates the request and the return
    /// is queued as a *new* job (FCFS position = return time). INFERCEPT
    /// and LAMPS keep the original arrival order.
    pub requeue_as_new: bool,
    /// Batch-composer knobs (token budget, chunked prefill, async swap).
    pub compose: ComposeConfig,
    /// Refcounted prefix caching in the KV block manager (off by
    /// default ⇒ byte-identical to the uncached engine).
    pub prefix_cache: PrefixCacheConfig,
    /// Engine replicas a [`ReplicaSet`](crate::cluster::ReplicaSet)
    /// composes over (`--replicas`). Each replica models one GPU with
    /// its own full `memory_budget`, swap space, and API executor. With
    /// `1` (the default) the single-engine path is used unchanged.
    pub replicas: usize,
    /// Cross-replica placement policy (`--placement`); only consulted
    /// when `replicas > 1`.
    pub placement: PlacementKind,
    /// Fleet-level shared prefix index (`--shared-prefix`): replicas
    /// journal their prefix-cache resident-set deltas and the
    /// [`ReplicaSet`](crate::cluster::ReplicaSet) mirrors them into a
    /// cross-replica hash→replicas map that prefix-affinity placement
    /// probes. Strictly advisory — a stale entry costs a re-prefill,
    /// never a correctness error — and off by default ⇒ byte-identical
    /// to the index-less fleet. Only meaningful alongside
    /// `prefix_cache.enabled` and `replicas > 1`.
    pub shared_prefix: bool,
    /// Placement-aware admission re-queue: a request OOM-rejected by
    /// its owner replica before it ever ran may be re-queued *once* to
    /// the best sibling with free KV instead of waiting out the
    /// owner's pressure (ROADMAP follow-on to multi-replica dispatch).
    /// Only applies with `replicas > 1`.
    pub admission_requeue: bool,
    /// Where API returns come from (`--api-source`): the simulated
    /// deadline heap (default; byte-identical to the pre-seam engine)
    /// or externally-resolved tool calls driven by the client over the
    /// session event stream.
    pub api_source: ApiSourceKind,
    /// API-duration estimation mode behind the predictor seam
    /// (`--api-pred`): [`ApiPredKind::Static`] (default, byte-identical
    /// to the pre-seam engine) or [`ApiPredKind::Learned`] online
    /// per-class estimators closing the predict→observe→re-rank loop.
    pub api_pred: ApiPredKind,
    /// Runtime invariant auditing (`--audit`): [`AuditMode::Auto`] by
    /// default, i.e. every debug-build (tier-1 test) engine/fleet step
    /// is audit-checked and release runs pay nothing unless opted in.
    pub audit: AuditMode,
    /// Epoch-keyed placement-score cache (`--placement-cache off` to
    /// disable): each engine memoizes its memory-over-time load
    /// aggregate and invalidates it on any state change, making
    /// placement probes O(1) between mutations. Decisions are
    /// byte-identical either way — a debug/audit shadow recompute
    /// enforces exact equality with the stateless oracle — so `off`
    /// exists only as an escape hatch and for A/B benchmarking.
    pub placement_cache: bool,
    /// Modeled cross-replica network (`--net-model` and friends):
    /// gossip-lagged shared-prefix mirror, bounded-staleness load
    /// digests, and elastic replica count. [`NetModelKind::Off`] by
    /// default ⇒ byte-identical to the net-less fleet.
    pub net: NetConfig,
    pub cost: CostModel,
    pub seed: u64,
}

impl Default for SystemConfig {
    fn default() -> SystemConfig {
        SystemConfig {
            scheduler: SchedulerKind::Lamps,
            handling: HandlingPolicy::MinWastePredicted,
            predictor: PredictorKind::Oracle,
            memory_budget: Tokens(44_000),
            max_batch: 64,
            block_size: 16,
            starvation_threshold: Some(100),
            score_update_interval: 1,
            admission_lookahead: true,
            requeue_as_new: false,
            compose: ComposeConfig::default(),
            prefix_cache: PrefixCacheConfig::default(),
            replicas: 1,
            placement: PlacementKind::MemoryOverTime,
            shared_prefix: false,
            admission_requeue: true,
            api_source: ApiSourceKind::default(),
            api_pred: ApiPredKind::default(),
            audit: AuditMode::default(),
            placement_cache: true,
            net: NetConfig::default(),
            cost: CostModel::paper_scale(),
            seed: 0,
        }
    }
}

impl SystemConfig {
    /// Named baseline presets (see module docs).
    pub fn preset(name: &str) -> Option<SystemConfig> {
        let base = SystemConfig::default();
        Some(match name {
            "vllm" => SystemConfig {
                scheduler: SchedulerKind::Fcfs,
                handling: HandlingPolicy::Forced(HandlingStrategy::Discard),
                requeue_as_new: true,
                ..base
            },
            "infercept" => SystemConfig {
                scheduler: SchedulerKind::Fcfs,
                handling: HandlingPolicy::MinWasteAtApi,
                ..base
            },
            "lamps" => base,
            "lamps-no-sched" => SystemConfig {
                scheduler: SchedulerKind::Fcfs,
                handling: HandlingPolicy::MinWastePredicted,
                ..base
            },
            "sjf" => SystemConfig {
                scheduler: SchedulerKind::Sjf,
                ..base
            },
            "sjf-total" => SystemConfig {
                scheduler: SchedulerKind::SjfTotal,
                ..base
            },
            _ => return None,
        })
    }

    pub fn with_seed(mut self, seed: u64) -> SystemConfig {
        self.seed = seed;
        self
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_exist() {
        for name in ["vllm", "infercept", "lamps", "lamps-no-sched", "sjf",
                     "sjf-total"] {
            assert!(SystemConfig::preset(name).is_some(), "{name}");
        }
        assert!(SystemConfig::preset("nope").is_none());
    }

    #[test]
    fn vllm_is_fcfs_discard() {
        let c = SystemConfig::preset("vllm").unwrap();
        assert_eq!(c.scheduler, SchedulerKind::Fcfs);
        assert_eq!(c.handling,
                   HandlingPolicy::Forced(HandlingStrategy::Discard));
    }

    #[test]
    fn cost_model_unit_mode() {
        let c = CostModel::unit();
        assert_eq!(c.decode_iter_time(Tokens(1000)), Micros(1_000_000));
        assert_eq!(c.prefill_time(Tokens(2)), Micros(2_000_000));
        assert_eq!(c.swap_time(Tokens(5)), Micros::ZERO);
    }

    #[test]
    fn compose_defaults_are_legacy() {
        let c = ComposeConfig::default();
        assert_eq!(c.max_batch_tokens, None);
        assert_eq!(c.prefill_chunk, None);
        assert!(!c.async_swap);
        assert!(!c.auto_chunk, "autotuning is opt-in");
        assert!(!c.is_chunked());
        assert!(ComposeConfig::chunked().is_chunked());
        // The chunked preset keeps the static 512 default; `auto` is a
        // separate opt-in.
        assert_eq!(ComposeConfig::chunked().prefill_chunk, Some(512));
        // Auto counts as chunked (the scheduler must account prefill).
        let auto = ComposeConfig {
            auto_chunk: true,
            ..ComposeConfig::default()
        };
        assert!(auto.is_chunked());
        // Presets must not silently enable the composer features.
        assert_eq!(SystemConfig::preset("lamps").unwrap().compose, c);
    }

    #[test]
    fn api_source_defaults_simulated_and_parses() {
        // `--api-source sim` (the default) must leave every preset on
        // the simulated deadline heap — the byte-identical-to-PR-4
        // path.
        assert_eq!(ApiSourceKind::default(), ApiSourceKind::Simulated);
        for name in ["vllm", "infercept", "lamps", "lamps-no-sched",
                     "sjf", "sjf-total"] {
            assert_eq!(SystemConfig::preset(name).unwrap().api_source,
                       ApiSourceKind::Simulated, "{name}");
        }
        for kind in [ApiSourceKind::Simulated, ApiSourceKind::External] {
            assert_eq!(ApiSourceKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(ApiSourceKind::parse("simulated"),
                   Some(ApiSourceKind::Simulated));
        assert_eq!(ApiSourceKind::parse("nope"), None);
    }

    #[test]
    fn api_pred_defaults_static_and_parses() {
        // `--api-pred static` (the default) must leave every preset on
        // the pass-through duration seam — the byte-identical path.
        assert_eq!(ApiPredKind::default(), ApiPredKind::Static);
        for name in ["vllm", "infercept", "lamps", "lamps-no-sched",
                     "sjf", "sjf-total"] {
            assert_eq!(SystemConfig::preset(name).unwrap().api_pred,
                       ApiPredKind::Static, "{name}");
        }
        for kind in [ApiPredKind::Static, ApiPredKind::Learned] {
            assert_eq!(ApiPredKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(ApiPredKind::parse("nope"), None);
    }

    #[test]
    fn prefix_cache_defaults_off() {
        let c = PrefixCacheConfig::default();
        assert!(!c.enabled);
        assert_eq!(c.cache_blocks, None);
        assert!(PrefixCacheConfig::on().enabled);
        // Presets must not silently enable the cache.
        for name in ["vllm", "infercept", "lamps"] {
            assert!(!SystemConfig::preset(name).unwrap()
                        .prefix_cache.enabled, "{name}");
        }
    }

    #[test]
    fn replica_defaults_are_single_engine() {
        let c = SystemConfig::default();
        assert_eq!(c.replicas, 1);
        assert_eq!(c.placement, PlacementKind::MemoryOverTime);
        assert!(!c.shared_prefix, "shared index must default off");
        assert!(c.admission_requeue,
                "admission re-queue is a bugfix, on by default");
        // Presets must not silently enable multi-replica dispatch or
        // the shared prefix index.
        for name in ["vllm", "infercept", "lamps"] {
            let p = SystemConfig::preset(name).unwrap();
            assert_eq!(p.replicas, 1, "{name}");
            assert!(!p.shared_prefix, "{name}");
        }
    }

    #[test]
    fn audit_defaults_auto_and_parses() {
        assert_eq!(AuditMode::default(), AuditMode::Auto);
        assert_eq!(SystemConfig::default().audit, AuditMode::Auto);
        // Auto tracks the build profile; On/Off override it.
        assert_eq!(AuditMode::Auto.enabled(), cfg!(debug_assertions));
        assert!(AuditMode::On.enabled());
        assert!(!AuditMode::Off.enabled());
        for mode in [AuditMode::Auto, AuditMode::On, AuditMode::Off] {
            assert_eq!(AuditMode::parse(mode.label()), Some(mode));
        }
        assert_eq!(AuditMode::parse("nope"), None);
        // Presets must not silently force auditing on or off.
        for name in ["vllm", "infercept", "lamps"] {
            assert_eq!(SystemConfig::preset(name).unwrap().audit,
                       AuditMode::Auto, "{name}");
        }
    }

    #[test]
    fn net_defaults_off_and_parses() {
        // `--net-model off` (the default) must leave every preset on
        // the sequentially-stepped exact-mirror fleet — the
        // byte-identical-to-PR-9 path.
        let c = NetConfig::default();
        assert_eq!(c.model, NetModelKind::Off);
        assert!(!c.armed(1));
        assert!(!c.armed(256), "off is off at any fleet size");
        assert_eq!(c.autoscale, None, "autoscale must default off");
        assert_eq!(SystemConfig::default().net, NetConfig::default());
        for name in ["vllm", "infercept", "lamps", "lamps-no-sched",
                     "sjf", "sjf-total"] {
            assert_eq!(SystemConfig::preset(name).unwrap().net.model,
                       NetModelKind::Off, "{name}");
        }
        for kind in [NetModelKind::Off, NetModelKind::Lan,
                     NetModelKind::Wan] {
            assert_eq!(NetModelKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(NetModelKind::parse("nope"), None);
        // Armed needs both a model and a fleet.
        let lan = NetConfig {
            model: NetModelKind::Lan,
            ..NetConfig::default()
        };
        assert!(!lan.armed(1), "a single engine has no links");
        assert!(lan.armed(2));
        // Delay bounds exist exactly for the modeled links.
        assert_eq!(NetModelKind::Off.delay_bounds_us(), None);
        for kind in [NetModelKind::Lan, NetModelKind::Wan] {
            let (lo, hi) = kind.delay_bounds_us().unwrap();
            assert!(lo < hi, "{kind:?}");
        }
    }

    #[test]
    fn autoscale_parse_roundtrip() {
        assert_eq!(AutoscaleConfig::parse("2:8"),
                   Some(AutoscaleConfig { min: 2, max: 8 }));
        assert_eq!(AutoscaleConfig::parse("4:4"),
                   Some(AutoscaleConfig { min: 4, max: 4 }));
        assert_eq!(AutoscaleConfig::parse("0:4"), None,
                   "min 0 would drain the whole fleet");
        assert_eq!(AutoscaleConfig::parse("8:2"), None,
                   "min > max is a config error");
        assert_eq!(AutoscaleConfig::parse("8"), None);
        assert_eq!(AutoscaleConfig::parse("a:b"), None);
    }

    #[test]
    fn placement_parse_roundtrip() {
        for kind in [PlacementKind::MemoryOverTime,
                     PlacementKind::PrefixAffinity,
                     PlacementKind::LeastLoaded,
                     PlacementKind::RoundRobin] {
            assert_eq!(PlacementKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(PlacementKind::parse("mot"),
                   Some(PlacementKind::MemoryOverTime));
        assert_eq!(PlacementKind::parse("affinity"),
                   Some(PlacementKind::PrefixAffinity));
        assert_eq!(PlacementKind::parse("nope"), None);
    }

    #[test]
    fn cost_model_paper_scale() {
        let c = CostModel::paper_scale();
        assert_eq!(c.decode_iter_time(Tokens(20_000)), Micros(30_000));
        assert_eq!(c.prefill_time(Tokens(100)), Micros(10_000));
        assert_eq!(c.swap_time(Tokens(1000)), Micros(31_000));
        assert_eq!(c.swap_time(Tokens(0)), Micros::ZERO);
    }
}
