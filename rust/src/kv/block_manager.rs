//! vLLM-style paged KV-cache block manager.
//!
//! The device KV budget is divided into fixed-size blocks of
//! `block_size` token slots. Each live request owns an ordered list of
//! physical blocks; the last block may be partially filled. This gives the
//! engine exact token-granular admission accounting (what the paper's
//! scheduler reasons about) plus the physical block indices the PJRT
//! backend uses to place sequences into fixed-shape cache slots.

use std::collections::HashMap;

use crate::core::types::{RequestId, Tokens};

/// Physical block index.
pub type BlockId = u32;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KvError {
    /// Not enough free blocks for the allocation. `free` is reported in
    /// the same unit the admission check uses: tokens the *requesting*
    /// allocation could actually get right now — whole free blocks plus
    /// the slack in the request's own partial last block (a bare
    /// whole-block count under-reports exactly when the last block is
    /// partial).
    OutOfMemory {
        requested: Tokens,
        free: Tokens,
    },
    /// Request has no allocation.
    UnknownRequest(RequestId),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfMemory { requested, free } => {
                write!(f, "KV OOM: requested {requested}, free {free}")
            }
            KvError::UnknownRequest(id) => {
                write!(f, "no KV allocation for {id}")
            }
        }
    }
}

impl std::error::Error for KvError {}

#[derive(Debug, Clone)]
struct Allocation {
    blocks: Vec<BlockId>,
    tokens: u64,
}

/// Paged block manager.
#[derive(Debug, Clone)]
pub struct BlockManager {
    block_size: u64,
    free_blocks: Vec<BlockId>,
    total_blocks: u64,
    allocs: HashMap<RequestId, Allocation>,
    /// Running sum of allocated tokens (logical).
    used_tokens: u64,
    /// High-water mark of block usage, for reporting.
    peak_blocks_used: u64,
}

impl BlockManager {
    /// `budget` is rounded *down* to whole blocks.
    pub fn new(budget: Tokens, block_size: u64) -> BlockManager {
        assert!(block_size > 0, "block_size must be positive");
        let total_blocks = budget.0 / block_size;
        BlockManager {
            block_size,
            free_blocks: (0..total_blocks as u32).rev().collect(),
            total_blocks,
            allocs: HashMap::new(),
            used_tokens: 0,
            peak_blocks_used: 0,
        }
    }

    pub fn block_size(&self) -> u64 {
        self.block_size
    }

    /// Token capacity (whole blocks).
    pub fn capacity(&self) -> Tokens {
        Tokens(self.total_blocks * self.block_size)
    }

    /// Tokens logically allocated.
    pub fn used_tokens(&self) -> Tokens {
        Tokens(self.used_tokens)
    }

    /// Tokens physically reserved (whole blocks), >= used_tokens.
    pub fn reserved_tokens(&self) -> Tokens {
        Tokens((self.total_blocks - self.free_blocks.len() as u64)
            * self.block_size)
    }

    /// Tokens still allocatable (whole-block granularity, i.e. what a new
    /// allocation can actually get).
    pub fn free_tokens(&self) -> Tokens {
        Tokens(self.free_blocks.len() as u64 * self.block_size)
    }

    /// Fraction of capacity physically in use, in [0, 1].
    pub fn occupancy(&self) -> f64 {
        if self.total_blocks == 0 {
            return 0.0;
        }
        1.0 - self.free_blocks.len() as f64 / self.total_blocks as f64
    }

    /// Internal fragmentation: reserved-but-unused token slots.
    pub fn fragmentation(&self) -> Tokens {
        self.reserved_tokens() - self.used_tokens()
    }

    pub fn peak_blocks_used(&self) -> u64 {
        self.peak_blocks_used
    }

    /// Does `req` have an allocation?
    pub fn contains(&self, req: RequestId) -> bool {
        self.allocs.contains_key(&req)
    }

    /// Tokens allocated to `req` (0 if none).
    pub fn tokens_of(&self, req: RequestId) -> Tokens {
        Tokens(self.allocs.get(&req).map(|a| a.tokens).unwrap_or(0))
    }

    /// Physical block list of `req`.
    pub fn blocks_of(&self, req: RequestId) -> Option<&[BlockId]> {
        self.allocs.get(&req).map(|a| a.blocks.as_slice())
    }

    /// Tokens `req` could grow by right now: whole free blocks plus the
    /// slack in its own partial last block. This is the exact bound
    /// `can_fit` enforces: `can_fit(req, t)` iff `t <= available_for(req)`.
    pub fn available_for(&self, req: RequestId) -> Tokens {
        let slack = self
            .allocs
            .get(&req)
            .map(|a| a.blocks.len() as u64 * self.block_size - a.tokens)
            .unwrap_or(0);
        Tokens(self.free_blocks.len() as u64 * self.block_size + slack)
    }

    /// Would an allocation/growth of `tokens` for `req` succeed right now?
    pub fn can_fit(&self, req: RequestId, tokens: Tokens) -> bool {
        let existing = self.allocs.get(&req);
        let cur_tokens = existing.map(|a| a.tokens).unwrap_or(0);
        let cur_blocks = existing.map(|a| a.blocks.len() as u64).unwrap_or(0);
        let needed_blocks =
            (cur_tokens + tokens.0).div_ceil(self.block_size);
        needed_blocks.saturating_sub(cur_blocks)
            <= self.free_blocks.len() as u64
    }

    /// Allocate (or grow by) `tokens` for `req`.
    pub fn allocate(&mut self, req: RequestId, tokens: Tokens)
                    -> Result<(), KvError> {
        if tokens == Tokens::ZERO {
            self.allocs.entry(req).or_insert(Allocation {
                blocks: Vec::new(),
                tokens: 0,
            });
            return Ok(());
        }
        if !self.can_fit(req, tokens) {
            return Err(KvError::OutOfMemory {
                requested: tokens,
                free: self.available_for(req),
            });
        }
        let alloc = self.allocs.entry(req).or_insert(Allocation {
            blocks: Vec::new(),
            tokens: 0,
        });
        let needed_blocks =
            (alloc.tokens + tokens.0).div_ceil(self.block_size);
        while (alloc.blocks.len() as u64) < needed_blocks {
            alloc.blocks.push(self.free_blocks.pop().expect("can_fit held"));
        }
        alloc.tokens += tokens.0;
        self.used_tokens += tokens.0;
        self.peak_blocks_used = self
            .peak_blocks_used
            .max(self.total_blocks - self.free_blocks.len() as u64);
        Ok(())
    }

    /// Grow `req` by one token (the per-iteration decode append).
    pub fn append_token(&mut self, req: RequestId) -> Result<(), KvError> {
        if !self.allocs.contains_key(&req) {
            return Err(KvError::UnknownRequest(req));
        }
        self.allocate(req, Tokens(1))
    }

    /// Release the entire allocation of `req`, returning its token count.
    pub fn free(&mut self, req: RequestId) -> Result<Tokens, KvError> {
        let alloc = self
            .allocs
            .remove(&req)
            .ok_or(KvError::UnknownRequest(req))?;
        self.free_blocks.extend(alloc.blocks.iter().rev());
        self.used_tokens -= alloc.tokens;
        Ok(Tokens(alloc.tokens))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rid(n: u64) -> RequestId {
        RequestId(n)
    }

    #[test]
    fn capacity_rounds_down() {
        let m = BlockManager::new(Tokens(100), 16);
        assert_eq!(m.capacity(), Tokens(96));
        assert_eq!(m.free_tokens(), Tokens(96));
    }

    #[test]
    fn allocate_and_free_roundtrip() {
        let mut m = BlockManager::new(Tokens(64), 16);
        m.allocate(rid(1), Tokens(20)).unwrap();
        assert_eq!(m.tokens_of(rid(1)), Tokens(20));
        assert_eq!(m.reserved_tokens(), Tokens(32)); // 2 blocks
        assert_eq!(m.fragmentation(), Tokens(12));
        assert_eq!(m.free(rid(1)).unwrap(), Tokens(20));
        assert_eq!(m.used_tokens(), Tokens::ZERO);
        assert_eq!(m.free_tokens(), Tokens(64));
    }

    #[test]
    fn append_token_grows_blocks_lazily() {
        let mut m = BlockManager::new(Tokens(32), 16);
        m.allocate(rid(1), Tokens(15)).unwrap();
        assert_eq!(m.blocks_of(rid(1)).unwrap().len(), 1);
        m.append_token(rid(1)).unwrap(); // 16th token: still 1 block
        assert_eq!(m.blocks_of(rid(1)).unwrap().len(), 1);
        m.append_token(rid(1)).unwrap(); // 17th: needs a second block
        assert_eq!(m.blocks_of(rid(1)).unwrap().len(), 2);
    }

    #[test]
    fn oom_reported_and_state_unchanged() {
        let mut m = BlockManager::new(Tokens(32), 16);
        m.allocate(rid(1), Tokens(30)).unwrap();
        let err = m.allocate(rid(2), Tokens(20)).unwrap_err();
        assert!(matches!(err, KvError::OutOfMemory { .. }));
        assert_eq!(m.tokens_of(rid(2)), Tokens::ZERO);
        assert!(!m.contains(rid(2)));
    }

    #[test]
    fn oom_reports_free_in_requester_tokens() {
        // r1 holds 10 of its 16-slot block: 6 slack + 1 free block = 22
        // tokens available *to r1*; a plain free-block count would say 16.
        let mut m = BlockManager::new(Tokens(32), 16);
        m.allocate(rid(1), Tokens(10)).unwrap();
        assert_eq!(m.available_for(rid(1)), Tokens(22));
        assert_eq!(m.available_for(rid(2)), Tokens(16));
        let err = m.allocate(rid(1), Tokens(23)).unwrap_err();
        assert_eq!(err, KvError::OutOfMemory {
            requested: Tokens(23),
            free: Tokens(22),
        });
        // The reported amount must itself be allocatable.
        m.allocate(rid(1), Tokens(22)).unwrap();
        assert_eq!(m.available_for(rid(1)), Tokens::ZERO);
    }

    #[test]
    fn can_fit_accounts_partial_last_block() {
        let mut m = BlockManager::new(Tokens(32), 16);
        m.allocate(rid(1), Tokens(10)).unwrap();
        // 6 slots left in r1's block + 1 free block = can fit 22 for r1...
        assert!(m.can_fit(rid(1), Tokens(22)));
        assert!(!m.can_fit(rid(1), Tokens(23)));
        // ...but a new request only gets whole free blocks.
        assert!(m.can_fit(rid(2), Tokens(16)));
        assert!(!m.can_fit(rid(2), Tokens(17)));
    }

    #[test]
    fn occupancy_and_peak() {
        let mut m = BlockManager::new(Tokens(64), 16);
        assert_eq!(m.occupancy(), 0.0);
        m.allocate(rid(1), Tokens(32)).unwrap();
        assert!((m.occupancy() - 0.5).abs() < 1e-9);
        m.free(rid(1)).unwrap();
        assert_eq!(m.occupancy(), 0.0);
        assert_eq!(m.peak_blocks_used(), 2);
    }

    #[test]
    fn unknown_request_errors() {
        let mut m = BlockManager::new(Tokens(32), 16);
        assert!(matches!(m.free(rid(9)), Err(KvError::UnknownRequest(_))));
        assert!(matches!(m.append_token(rid(9)),
                         Err(KvError::UnknownRequest(_))));
    }

    #[test]
    fn blocks_are_unique_across_requests() {
        let mut m = BlockManager::new(Tokens(64), 16);
        m.allocate(rid(1), Tokens(20)).unwrap();
        m.allocate(rid(2), Tokens(20)).unwrap();
        let b1 = m.blocks_of(rid(1)).unwrap().to_vec();
        let b2 = m.blocks_of(rid(2)).unwrap().to_vec();
        for b in &b1 {
            assert!(!b2.contains(b));
        }
    }
}
