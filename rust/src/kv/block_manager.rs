//! vLLM-style paged KV-cache block manager with optional refcounted
//! prefix caching.
//!
//! The device KV budget is divided into fixed-size blocks of
//! `block_size` token slots. Each live request owns an ordered list of
//! physical blocks; the last block may be partially filled. This gives the
//! engine exact token-granular admission accounting (what the paper's
//! scheduler reasons about) plus the physical block indices the PJRT
//! backend uses to place sequences into fixed-shape cache slots.
//!
//! With a [`PrefixCache`] attached (see [`BlockManager::with_prefix_cache`]
//! and [`crate::kv::prefix`]), full blocks of identical context prefixes
//! are hash-consed: [`BlockManager::allocate_prefixed`] pins
//! already-materialized blocks instead of allocating fresh ones, frees
//! retain zero-ref shared blocks in a reclaimable LRU, and OOM accounting
//! distinguishes three physical states — **pinned** (held by at least one
//! allocation, never reclaimable), **cached** (zero-ref, reclaimed under
//! pressure before OOM is reported), and **free**. Without a cache every
//! code path below reduces to the original manager exactly.

use std::collections::HashMap;

use super::prefix::{BlockHash, PrefixCache, PrefixDelta};
use crate::core::types::{RequestId, Tokens};

/// Physical block index.
pub type BlockId = u32;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KvError {
    /// Not enough free blocks for the allocation. `free` is reported in
    /// the same unit the admission check uses: tokens the *requesting*
    /// allocation could actually get right now — whole free blocks, plus
    /// zero-ref cached blocks reclaimable under pressure, plus the slack
    /// in the request's own partial last block (and, on the prefixed
    /// path, the leading cached chain hits it could share). Blocks
    /// pinned by other requests' refcounts are otherwise excluded: they
    /// are not available to anyone until every holder frees them.
    OutOfMemory {
        requested: Tokens,
        free: Tokens,
    },
    /// Request has no allocation.
    UnknownRequest(RequestId),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfMemory { requested, free } => {
                write!(f, "KV OOM: requested {requested}, free {free}")
            }
            KvError::UnknownRequest(id) => {
                write!(f, "no KV allocation for {id}")
            }
        }
    }
}

impl std::error::Error for KvError {}

#[derive(Debug, Clone)]
struct Allocation {
    blocks: Vec<BlockId>,
    /// Parallel to `blocks`: the prefix-cache chain hash for blocks this
    /// allocation holds a refcount on (`None` for private blocks; always
    /// all-`None` when the manager has no prefix cache).
    hashes: Vec<Option<BlockHash>>,
    tokens: u64,
}

impl Allocation {
    fn empty() -> Allocation {
        Allocation {
            blocks: Vec::new(),
            hashes: Vec::new(),
            tokens: 0,
        }
    }
}

/// Paged block manager.
#[derive(Debug, Clone)]
pub struct BlockManager {
    block_size: u64,
    free_blocks: Vec<BlockId>,
    total_blocks: u64,
    allocs: HashMap<RequestId, Allocation>,
    /// Running sum of allocated tokens (logical; with prefix sharing the
    /// sum over requests may exceed physical capacity).
    used_tokens: u64,
    /// High-water mark of block usage, for reporting.
    peak_blocks_used: u64,
    /// Fresh physical-block materializations (free-list pops); cache
    /// hits do not count — the before/after metric of prefix caching.
    blocks_allocated: u64,
    /// Refcounted prefix cache; `None` = disabled (legacy behavior).
    prefix: Option<PrefixCache>,
}

impl BlockManager {
    /// `budget` is rounded *down* to whole blocks.
    pub fn new(budget: Tokens, block_size: u64) -> BlockManager {
        assert!(block_size > 0, "block_size must be positive");
        let total_blocks = budget.0 / block_size;
        BlockManager {
            block_size,
            free_blocks: (0..total_blocks as u32).rev().collect(),
            total_blocks,
            allocs: HashMap::new(),
            used_tokens: 0,
            peak_blocks_used: 0,
            blocks_allocated: 0,
            prefix: None,
        }
    }

    /// Manager with a refcounted prefix cache attached. `cache_blocks`
    /// caps the zero-ref cached blocks retained after frees (`None` =
    /// retain all; memory pressure still reclaims them before OOM).
    pub fn with_prefix_cache(budget: Tokens, block_size: u64,
                             cache_blocks: Option<u64>) -> BlockManager {
        let mut m = BlockManager::new(budget, block_size);
        m.prefix = Some(PrefixCache::new(cache_blocks));
        m
    }

    pub fn block_size(&self) -> u64 {
        self.block_size
    }

    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Token capacity (whole blocks).
    pub fn capacity(&self) -> Tokens {
        Tokens(self.total_blocks * self.block_size)
    }

    /// Tokens logically allocated.
    pub fn used_tokens(&self) -> Tokens {
        Tokens(self.used_tokens)
    }

    /// Tokens physically reserved (whole non-free blocks, including
    /// zero-ref cached blocks), >= used_tokens when nothing is shared.
    pub fn reserved_tokens(&self) -> Tokens {
        Tokens((self.total_blocks - self.free_blocks.len() as u64)
            * self.block_size)
    }

    /// Tokens on the free list (whole-block granularity). Does not count
    /// reclaimable cached blocks; see [`BlockManager::available_for`]
    /// for what an allocation can actually get.
    pub fn free_tokens(&self) -> Tokens {
        Tokens(self.free_blocks.len() as u64 * self.block_size)
    }

    /// Zero-ref cached blocks (reclaimable under memory pressure).
    pub fn cached_blocks(&self) -> u64 {
        self.prefix.as_ref().map_or(0, |p| p.zero_ref())
    }

    /// Blocks held by at least one allocation (never reclaimable).
    pub fn pinned_blocks(&self) -> u64 {
        self.total_blocks
            - self.free_blocks.len() as u64
            - self.cached_blocks()
    }

    /// Fresh physical-block materializations so far (cache hits do not
    /// count).
    pub fn blocks_allocated(&self) -> u64 {
        self.blocks_allocated
    }

    /// Tokens served from prefix-cache hits instead of being prefilled.
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.prefix.as_ref().map_or(0, |p| p.hit_tokens())
    }

    /// Zero-ref cached blocks evicted (capacity or memory pressure).
    pub fn prefix_evictions(&self) -> u64 {
        self.prefix.as_ref().map_or(0, |p| p.evictions())
    }

    /// Refcount of a cached chain hash (`None` when absent or when the
    /// cache is disabled) — introspection for tests and debugging.
    pub fn prefix_refcount(&self, hash: BlockHash) -> Option<u32> {
        self.prefix.as_ref().and_then(|p| p.refcount_of(hash))
    }

    /// Start journaling the prefix cache's resident-set deltas (see
    /// [`PrefixDelta`]); no-op without a cache. A fleet driver drains
    /// them via [`BlockManager::drain_prefix_deltas`] to mirror this
    /// replica's resident hashes into a cross-replica index.
    pub fn enable_prefix_journal(&mut self) {
        if let Some(p) = self.prefix.as_mut() {
            p.enable_journal();
        }
    }

    /// Take the resident-set deltas journaled since the last drain
    /// (empty without a cache or with the journal unarmed).
    pub fn drain_prefix_deltas(&mut self) -> Vec<PrefixDelta> {
        self.prefix
            .as_mut()
            .map(|p| p.drain_journal())
            .unwrap_or_default()
    }

    /// Every hash resident in the prefix cache (any refcount), sorted —
    /// ground truth for fleet-level index invariants.
    pub fn resident_prefix_hashes(&self) -> Vec<BlockHash> {
        self.prefix
            .as_ref()
            .map(|p| p.resident_hashes())
            .unwrap_or_default()
    }

    /// Consecutive leading blocks of `chain` resident in the local
    /// prefix cache, in tokens — the replica-local ground truth a
    /// (possibly stale) fleet-level cached-token credit is measured
    /// against. 0 without a cache. Consecutive-only matches what
    /// [`BlockManager::allocate_prefixed`] can actually serve.
    pub fn cached_lead_tokens(&self, chain: &[BlockHash]) -> u64 {
        let Some(cache) = self.prefix.as_ref() else {
            return 0;
        };
        let mut lead = 0u64;
        for hash in chain {
            if !cache.contains(*hash) {
                break;
            }
            lead += self.block_size;
        }
        lead
    }

    /// Warm-up pre-seeding: adopt up to `max_blocks` of `hashes` into
    /// the local prefix cache as zero-ref cached blocks, drawing
    /// physical blocks from the free list only (never evicting live
    /// work). Models a warm sibling streaming its resident prefix
    /// blocks to a freshly activated replica. Already-resident hashes
    /// are skipped; each adoption is journaled like any other
    /// resident-set change, so gossip mirrors the seeded blocks. The
    /// retention cap is re-applied afterwards. Returns blocks seeded.
    pub fn preseed_cached(&mut self, hashes: &[BlockHash],
                          max_blocks: u64) -> u64 {
        let mut seeded = 0u64;
        for &hash in hashes {
            if seeded >= max_blocks {
                break;
            }
            let Some(cache) = self.prefix.as_mut() else {
                break;
            };
            if cache.contains(hash) {
                continue;
            }
            let Some(block) = self.free_blocks.pop() else {
                break;
            };
            if cache.register(hash, block) {
                // Drop the registration pin: zero-ref cached, exactly
                // the state a locally-warmed-then-released block lands
                // in, reclaimable under pressure.
                cache.release(hash);
                seeded += 1;
            } else {
                self.free_blocks.push(block);
            }
        }
        if seeded > 0 {
            if let Some(cache) = self.prefix.as_mut() {
                let evicted = cache.evict_over_capacity();
                self.free_blocks.extend(evicted);
            }
            self.note_peak();
        }
        seeded
    }

    /// Fraction of capacity physically in use (non-free blocks,
    /// including reclaimable cached ones), in [0, 1].
    pub fn occupancy(&self) -> f64 {
        if self.total_blocks == 0 {
            return 0.0;
        }
        1.0 - self.free_blocks.len() as f64 / self.total_blocks as f64
    }

    /// Internal fragmentation: reserved-but-unused token slots.
    pub fn fragmentation(&self) -> Tokens {
        self.reserved_tokens() - self.used_tokens()
    }

    pub fn peak_blocks_used(&self) -> u64 {
        self.peak_blocks_used
    }

    /// Does `req` have an allocation?
    pub fn contains(&self, req: RequestId) -> bool {
        self.allocs.contains_key(&req)
    }

    /// Tokens allocated to `req` (0 if none).
    pub fn tokens_of(&self, req: RequestId) -> Tokens {
        Tokens(self.allocs.get(&req).map(|a| a.tokens).unwrap_or(0))
    }

    /// Physical block list of `req`.
    pub fn blocks_of(&self, req: RequestId) -> Option<&[BlockId]> {
        self.allocs.get(&req).map(|a| a.blocks.as_slice())
    }

    /// Blocks usable by a new or growing allocation right now: the free
    /// list plus zero-ref cached blocks reclaimable under pressure.
    fn allocatable_blocks(&self) -> u64 {
        self.free_blocks.len() as u64 + self.cached_blocks()
    }

    /// Tokens `req` could grow by right now: whole free blocks, plus
    /// reclaimable zero-ref cached blocks, plus the slack in its own
    /// partial last block — and *excluding* blocks pinned by other
    /// requests. This is the exact bound `can_fit` enforces:
    /// `can_fit(req, t)` iff `t <= available_for(req)`.
    pub fn available_for(&self, req: RequestId) -> Tokens {
        let slack = self
            .allocs
            .get(&req)
            .map(|a| a.blocks.len() as u64 * self.block_size - a.tokens)
            .unwrap_or(0);
        Tokens(self.allocatable_blocks() * self.block_size + slack)
    }

    /// Would an allocation/growth of `tokens` for `req` succeed right now?
    pub fn can_fit(&self, req: RequestId, tokens: Tokens) -> bool {
        let existing = self.allocs.get(&req);
        let cur_tokens = existing.map(|a| a.tokens).unwrap_or(0);
        let cur_blocks = existing.map(|a| a.blocks.len() as u64).unwrap_or(0);
        let needed_blocks =
            (cur_tokens + tokens.0).div_ceil(self.block_size);
        needed_blocks.saturating_sub(cur_blocks)
            <= self.allocatable_blocks()
    }

    /// Pop one free block, reclaiming a zero-ref cached block first when
    /// the free list is empty. The caller must have checked fit.
    fn pop_free_block(&mut self) -> BlockId {
        if self.free_blocks.is_empty() {
            let reclaimed = self
                .prefix
                .as_mut()
                .and_then(|p| p.reclaim_one())
                // lamps-lint: allow(panic) can_fit verified a reclaimable zero-ref block exists
                .expect("fit check guaranteed a reclaimable block");
            self.free_blocks.push(reclaimed);
        }
        self.blocks_allocated += 1;
        // lamps-lint: allow(panic) pop_free_block refills the free list just above
        self.free_blocks.pop().expect("free list non-empty")
    }

    fn note_peak(&mut self) {
        self.peak_blocks_used = self
            .peak_blocks_used
            .max(self.total_blocks - self.free_blocks.len() as u64);
    }

    /// Allocate (or grow by) `tokens` for `req`.
    pub fn allocate(&mut self, req: RequestId, tokens: Tokens)
                    -> Result<(), KvError> {
        if tokens == Tokens::ZERO {
            self.allocs.entry(req).or_insert_with(Allocation::empty);
            return Ok(());
        }
        if !self.can_fit(req, tokens) {
            return Err(KvError::OutOfMemory {
                requested: tokens,
                free: self.available_for(req),
            });
        }
        let needed_blocks = {
            let alloc = self.allocs.entry(req).or_insert_with(
                Allocation::empty);
            (alloc.tokens + tokens.0).div_ceil(self.block_size)
        };
        // lamps-lint: allow(panic) the entry was created by the or_insert_with above
        while (self.allocs[&req].blocks.len() as u64) < needed_blocks {
            let block = self.pop_free_block();
            // lamps-lint: allow(panic) the entry was created by the or_insert_with above
            let alloc = self.allocs.get_mut(&req).expect("entry above");
            alloc.blocks.push(block);
            alloc.hashes.push(None);
        }
        // lamps-lint: allow(panic) the entry was created by the or_insert_with above
        let alloc = self.allocs.get_mut(&req).expect("entry above");
        alloc.tokens += tokens.0;
        self.used_tokens += tokens.0;
        self.note_peak();
        Ok(())
    }

    /// Allocate `tokens` for a *fresh* allocation of `req`, reusing
    /// cached prefix blocks. `chain` gives the content chain hashes of
    /// the leading full blocks (see [`crate::kv::prefix::content_chain`]);
    /// every leading hash already in the cache is pinned instead of
    /// materialized, and the returned token count (a multiple of
    /// `block_size`) is how much context the caller may skip prefilling.
    ///
    /// Falls back to a plain [`BlockManager::allocate`] (returning zero
    /// cached tokens) when the cache is disabled, the chain is empty, or
    /// `req` already holds blocks (growth never re-walks the trie).
    pub fn allocate_prefixed(&mut self, req: RequestId, tokens: Tokens,
                             chain: &[BlockHash])
                             -> Result<Tokens, KvError> {
        let fresh_alloc = match self.allocs.get(&req) {
            Some(a) => a.blocks.is_empty(),
            None => true,
        };
        if self.prefix.is_none() || chain.is_empty() || !fresh_alloc
            || tokens == Tokens::ZERO
        {
            self.allocate(req, tokens)?;
            return Ok(Tokens::ZERO);
        }

        // Phase 1 (read-only): walk the chain for consecutive leading
        // hits, then check the remainder fits without touching state —
        // a failed allocation must leave everything unchanged.
        // lamps-lint: allow(panic) the prefix-cache presence was checked by the caller
        let cache = self.prefix.as_ref().expect("checked above");
        let full_blocks =
            (tokens.0 / self.block_size).min(chain.len() as u64) as usize;
        let mut hits = 0usize;
        // lamps-lint: allow(panic) hits < full_blocks <= chain.len()
        while hits < full_blocks && cache.contains(chain[hits]) {
            hits += 1;
        }
        // Zero-ref blocks we are about to pin cannot also be reclaimed
        // to satisfy the fresh remainder.
        // lamps-lint: allow(panic) hits is bounded by chain.len()
        let zero_ref_hits = chain[..hits]
            .iter()
            .filter(|h| !cache.is_pinned(**h))
            .count() as u64;
        let needed_blocks = tokens.0.div_ceil(self.block_size);
        let fresh = needed_blocks - hits as u64;
        let usable = self.allocatable_blocks() - zero_ref_hits;
        if fresh > usable {
            return Err(KvError::OutOfMemory {
                requested: tokens,
                // The prefixed-path bound, not `available_for`: the
                // leading cached hits come on top of the fresh blocks
                // this chain leaves usable, so this is exactly what a
                // smaller prefixed allocation could still get.
                free: Tokens((hits as u64 + usable) * self.block_size),
            });
        }

        // Phase 2: pin the hits, then materialize the remainder.
        let mut blocks = Vec::with_capacity(needed_blocks as usize);
        let mut hashes = Vec::with_capacity(needed_blocks as usize);
        {
            // lamps-lint: allow(panic) the prefix-cache presence was checked by the caller
            let cache = self.prefix.as_mut().expect("checked above");
            // lamps-lint: allow(panic) hits is bounded by chain.len()
            for &hash in &chain[..hits] {
                // lamps-lint: allow(panic) the read-only hit walk saw this hash in the cache
                blocks.push(cache.pin(hash).expect("hit walk saw it"));
                hashes.push(Some(hash));
            }
        }
        for _ in 0..fresh {
            blocks.push(self.pop_free_block());
            hashes.push(None);
        }
        let cached_tokens = hits as u64 * self.block_size;
        if let Some(cache) = self.prefix.as_mut() {
            cache.note_hit_tokens(cached_tokens);
        }
        self.allocs.insert(req, Allocation {
            blocks,
            hashes,
            tokens: tokens.0,
        });
        self.used_tokens += tokens.0;
        self.note_peak();
        Ok(Tokens(cached_tokens))
    }

    /// Purge the zero-ref cached blocks of `chain` beyond the first
    /// `retain` entries — a request's private content (generated
    /// tokens, synthetic prompts) that can never be re-hit once the
    /// request is gone, including blocks registered at a Swap encounter
    /// that were never re-attached to an allocation. Entries pinned by
    /// another holder or already absent are left untouched. No-op when
    /// the cache is disabled.
    pub fn purge_chain_tail(&mut self, chain: &[BlockHash],
                            retain: u64) {
        let Some(cache) = self.prefix.as_mut() else {
            return;
        };
        for &hash in chain.iter().skip(retain as usize) {
            if let Some(freed) = cache.purge_zero_ref(hash) {
                self.free_blocks.push(freed);
            }
        }
    }

    /// Publish `req`'s materialized full blocks into the prefix cache so
    /// later allocations (other requests with the same prompt, or this
    /// request's own post-Discard recompute) can hit them. `materialized`
    /// is how many leading context tokens are content-complete; `chain`
    /// their content hashes. Idempotent; no-op without a cache.
    pub fn register_prefix(&mut self, req: RequestId,
                           materialized: Tokens, chain: &[BlockHash]) {
        if self.prefix.is_none() {
            return;
        }
        let Some(alloc) = self.allocs.get_mut(&req) else {
            return;
        };
        let full = (materialized.0 / self.block_size)
            .min(chain.len() as u64)
            .min(alloc.blocks.len() as u64) as usize;
        // lamps-lint: allow(panic) register_prefix is only called with a prefix cache configured
        let cache = self.prefix.as_mut().expect("checked above");
        for i in 0..full {
            // lamps-lint: allow(panic) full is min-clamped to both hashes and chain lengths
            if alloc.hashes[i].is_none()
                // lamps-lint: allow(panic) full is min-clamped to both hashes and chain lengths
                && cache.register(chain[i], alloc.blocks[i])
            {
                // lamps-lint: allow(panic) full is min-clamped to both hashes and chain lengths
                alloc.hashes[i] = Some(chain[i]);
            }
        }
    }

    /// Grow `req` by one token (the per-iteration decode append).
    pub fn append_token(&mut self, req: RequestId) -> Result<(), KvError> {
        if !self.allocs.contains_key(&req) {
            return Err(KvError::UnknownRequest(req));
        }
        self.allocate(req, Tokens(1))
    }

    /// Release the entire allocation of `req`, returning its token count.
    /// Shared blocks drop one refcount and are retained (reclaimable) in
    /// the cache at zero refs; private blocks return to the free list.
    pub fn free(&mut self, req: RequestId) -> Result<Tokens, KvError> {
        self.free_inner(req, u64::MAX)
    }

    /// Release `req` like [`BlockManager::free`], but hashed blocks at
    /// index >= `retain_blocks` are *purged* from the cache (straight
    /// back to the free list) once their refcount drains. The engine
    /// passes the request's shareable-prompt block count at finish, so
    /// request-private content (generated tokens, synthetic prompts)
    /// never lingers as permanently-unhittable cached garbage while
    /// shareable prompt blocks stay re-hittable.
    pub fn free_discarding_private(&mut self, req: RequestId,
                                   retain_blocks: u64)
                                   -> Result<Tokens, KvError> {
        self.free_inner(req, retain_blocks)
    }

    fn free_inner(&mut self, req: RequestId, retain_blocks: u64)
                  -> Result<Tokens, KvError> {
        let alloc = self
            .allocs
            .remove(&req)
            .ok_or(KvError::UnknownRequest(req))?;
        for i in (0..alloc.blocks.len()).rev() {
            // lamps-lint: allow(panic) blocks and hashes are pushed in lock-step
            match alloc.hashes[i] {
                Some(h) => {
                    let cache = self
                        .prefix
                        .as_mut()
                        // lamps-lint: allow(panic) a hashed block can only exist with a prefix cache
                        .expect("hashed block implies cache");
                    cache.release(h);
                    if i as u64 >= retain_blocks {
                        if let Some(freed) = cache.purge_zero_ref(h) {
                            self.free_blocks.push(freed);
                        }
                    }
                }
                // lamps-lint: allow(panic) i < alloc.blocks.len() by the loop bound
                None => self.free_blocks.push(alloc.blocks[i]),
            }
        }
        if let Some(cache) = self.prefix.as_mut() {
            let evicted = cache.evict_over_capacity();
            self.free_blocks.extend(evicted);
        }
        self.used_tokens -= alloc.tokens;
        Ok(Tokens(alloc.tokens))
    }

    /// Audit self-check ([`crate::audit`]), promoting the shadow-model
    /// invariants of `tests/kv_properties.rs` into the manager itself:
    /// free-list integrity, logical token accounting, per-hash
    /// refcounts equal to the number of allocation holders (all on the
    /// canonical physical block), and block conservation — free,
    /// pinned, and cached blocks exactly partition the capacity.
    /// Read-only.
    pub fn check_invariants(&self) -> Result<(), String> {
        // Free-list integrity: in range, no duplicates.
        let mut free = self.free_blocks.clone();
        free.sort_unstable();
        free.dedup();
        if free.len() != self.free_blocks.len() {
            return Err("duplicate block on the free list".to_string());
        }
        if free.iter().any(|&b| u64::from(b) >= self.total_blocks) {
            return Err("free list holds an out-of-range block"
                .to_string());
        }
        // Logical token accounting.
        let alloc_tokens: u64 =
            self.allocs.values().map(|a| a.tokens).sum();
        if alloc_tokens != self.used_tokens {
            return Err(format!(
                "used_tokens {} != sum of allocations {alloc_tokens}",
                self.used_tokens));
        }
        // Per-allocation shape, hash holders, and the private set.
        let mut holders: HashMap<BlockHash, u32> = HashMap::new();
        let mut held: Vec<BlockId> = Vec::new();
        for (id, alloc) in &self.allocs {
            if alloc.blocks.len() != alloc.hashes.len() {
                return Err(format!(
                    "{id}: blocks/hashes length mismatch"));
            }
            if alloc.tokens
                > alloc.blocks.len() as u64 * self.block_size
            {
                return Err(format!(
                    "{id}: {} tokens exceed its {} blocks",
                    alloc.tokens,
                    alloc.blocks.len()));
            }
            for (block, hash) in alloc.blocks.iter().zip(&alloc.hashes)
            {
                match hash {
                    Some(h) => {
                        let canonical = self
                            .prefix
                            .as_ref()
                            .and_then(|p| p.block_of(*h));
                        if canonical != Some(*block) {
                            return Err(format!(
                                "{id}: holds hashed block {block} but \
                                 the canonical cached block is \
                                 {canonical:?}"));
                        }
                        *holders.entry(*h).or_insert(0) += 1;
                    }
                    None => held.push(*block),
                }
            }
        }
        // Private blocks are uniquely owned.
        let private_count = held.len();
        held.sort_unstable();
        held.dedup();
        if held.len() != private_count {
            return Err("a private block has two holders".to_string());
        }
        // Cache cross-check: every refcount equals its holder count,
        // and cache-owned blocks join the held set exactly once each.
        let mut pinned_cache = 0u64;
        if let Some(cache) = self.prefix.as_ref() {
            cache.check_invariants()?;
            for hash in cache.resident_hashes() {
                let refs = cache.refcount_of(hash).unwrap_or(0);
                let holding =
                    holders.get(&hash).copied().unwrap_or(0);
                if refs != holding {
                    return Err(format!(
                        "hash {hash:#x}: refcount {refs} != \
                         {holding} allocation holders"));
                }
                if refs > 0 {
                    pinned_cache += 1;
                }
                if let Some(block) = cache.block_of(hash) {
                    held.push(block);
                }
            }
            let with_cache = held.len();
            held.sort_unstable();
            held.dedup();
            if held.len() != with_cache {
                return Err("a cached block aliases a private block"
                    .to_string());
            }
            for hash in holders.keys() {
                if cache.refcount_of(*hash).is_none() {
                    return Err(format!(
                        "allocation holds hash {hash:#x} absent from \
                         the cache"));
                }
            }
        } else if !holders.is_empty() {
            return Err("hashed blocks without a prefix cache"
                .to_string());
        }
        // Block conservation: free + pinned + cached must exactly
        // partition the capacity (disjoint and complete).
        let held_count = held.len() as u64;
        let mut all = held;
        all.extend(free.iter().copied());
        let combined = all.len() as u64;
        all.sort_unstable();
        all.dedup();
        if combined != self.total_blocks
            || all.len() as u64 != self.total_blocks
        {
            return Err(format!(
                "block conservation: {} free + {held_count} held does \
                 not partition {} total blocks",
                free.len(),
                self.total_blocks));
        }
        // The derived pinned gauge agrees with the physical partition.
        if self.pinned_blocks() != private_count as u64 + pinned_cache {
            return Err(format!(
                "pinned gauge {} != {private_count} private + \
                 {pinned_cache} pinned cached blocks",
                self.pinned_blocks()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rid(n: u64) -> RequestId {
        RequestId(n)
    }

    #[test]
    fn capacity_rounds_down() {
        let m = BlockManager::new(Tokens(100), 16);
        assert_eq!(m.capacity(), Tokens(96));
        assert_eq!(m.free_tokens(), Tokens(96));
    }

    #[test]
    fn allocate_and_free_roundtrip() {
        let mut m = BlockManager::new(Tokens(64), 16);
        m.allocate(rid(1), Tokens(20)).unwrap();
        assert_eq!(m.tokens_of(rid(1)), Tokens(20));
        assert_eq!(m.reserved_tokens(), Tokens(32)); // 2 blocks
        assert_eq!(m.fragmentation(), Tokens(12));
        assert_eq!(m.free(rid(1)).unwrap(), Tokens(20));
        assert_eq!(m.used_tokens(), Tokens::ZERO);
        assert_eq!(m.free_tokens(), Tokens(64));
    }

    #[test]
    fn append_token_grows_blocks_lazily() {
        let mut m = BlockManager::new(Tokens(32), 16);
        m.allocate(rid(1), Tokens(15)).unwrap();
        assert_eq!(m.blocks_of(rid(1)).unwrap().len(), 1);
        m.append_token(rid(1)).unwrap(); // 16th token: still 1 block
        assert_eq!(m.blocks_of(rid(1)).unwrap().len(), 1);
        m.append_token(rid(1)).unwrap(); // 17th: needs a second block
        assert_eq!(m.blocks_of(rid(1)).unwrap().len(), 2);
    }

    #[test]
    fn oom_reported_and_state_unchanged() {
        let mut m = BlockManager::new(Tokens(32), 16);
        m.allocate(rid(1), Tokens(30)).unwrap();
        let err = m.allocate(rid(2), Tokens(20)).unwrap_err();
        assert!(matches!(err, KvError::OutOfMemory { .. }));
        assert_eq!(m.tokens_of(rid(2)), Tokens::ZERO);
        assert!(!m.contains(rid(2)));
    }

    #[test]
    fn oom_reports_free_in_requester_tokens() {
        // r1 holds 10 of its 16-slot block: 6 slack + 1 free block = 22
        // tokens available *to r1*; a plain free-block count would say 16.
        let mut m = BlockManager::new(Tokens(32), 16);
        m.allocate(rid(1), Tokens(10)).unwrap();
        assert_eq!(m.available_for(rid(1)), Tokens(22));
        assert_eq!(m.available_for(rid(2)), Tokens(16));
        let err = m.allocate(rid(1), Tokens(23)).unwrap_err();
        assert_eq!(err, KvError::OutOfMemory {
            requested: Tokens(23),
            free: Tokens(22),
        });
        // The reported amount must itself be allocatable.
        m.allocate(rid(1), Tokens(22)).unwrap();
        assert_eq!(m.available_for(rid(1)), Tokens::ZERO);
    }

    #[test]
    fn can_fit_accounts_partial_last_block() {
        let mut m = BlockManager::new(Tokens(32), 16);
        m.allocate(rid(1), Tokens(10)).unwrap();
        // 6 slots left in r1's block + 1 free block = can fit 22 for r1...
        assert!(m.can_fit(rid(1), Tokens(22)));
        assert!(!m.can_fit(rid(1), Tokens(23)));
        // ...but a new request only gets whole free blocks.
        assert!(m.can_fit(rid(2), Tokens(16)));
        assert!(!m.can_fit(rid(2), Tokens(17)));
    }

    #[test]
    fn occupancy_and_peak() {
        let mut m = BlockManager::new(Tokens(64), 16);
        assert_eq!(m.occupancy(), 0.0);
        m.allocate(rid(1), Tokens(32)).unwrap();
        assert!((m.occupancy() - 0.5).abs() < 1e-9);
        m.free(rid(1)).unwrap();
        assert_eq!(m.occupancy(), 0.0);
        assert_eq!(m.peak_blocks_used(), 2);
    }

    #[test]
    fn unknown_request_errors() {
        let mut m = BlockManager::new(Tokens(32), 16);
        assert!(matches!(m.free(rid(9)), Err(KvError::UnknownRequest(_))));
        assert!(matches!(m.append_token(rid(9)),
                         Err(KvError::UnknownRequest(_))));
    }

    #[test]
    fn blocks_are_unique_across_requests() {
        let mut m = BlockManager::new(Tokens(64), 16);
        m.allocate(rid(1), Tokens(20)).unwrap();
        m.allocate(rid(2), Tokens(20)).unwrap();
        let b1 = m.blocks_of(rid(1)).unwrap().to_vec();
        let b2 = m.blocks_of(rid(2)).unwrap().to_vec();
        for b in &b1 {
            assert!(!b2.contains(b));
        }
    }

    // ---- prefix-cache behavior ----

    fn cached_mgr(budget: u64, bs: u64) -> BlockManager {
        BlockManager::with_prefix_cache(Tokens(budget), bs, None)
    }

    #[test]
    fn prefixed_hit_shares_physical_blocks() {
        let mut m = cached_mgr(16 * 8, 16);
        let chain = [101, 102];
        // First request materializes 40 tokens (2 full + 1 partial).
        assert_eq!(m.allocate_prefixed(rid(1), Tokens(40), &chain)
                       .unwrap(),
                   Tokens::ZERO);
        m.register_prefix(rid(1), Tokens(40), &chain);
        let b1 = m.blocks_of(rid(1)).unwrap().to_vec();
        // Second request with the same chain reuses both full blocks.
        assert_eq!(m.allocate_prefixed(rid(2), Tokens(40), &chain)
                       .unwrap(),
                   Tokens(32));
        let b2 = m.blocks_of(rid(2)).unwrap().to_vec();
        assert_eq!(b1[..2], b2[..2], "full prefix blocks are shared");
        assert_ne!(b1[2], b2[2], "partial tails stay private");
        assert_eq!(m.prefix_hit_tokens(), 32);
        // Physical usage: 2 shared + 2 private tails = 4 blocks.
        assert_eq!(m.pinned_blocks(), 4);
        assert_eq!(m.blocks_allocated(), 4, "hits are not materializations");
    }

    #[test]
    fn free_retains_shared_blocks_for_rehits() {
        let mut m = cached_mgr(16 * 4, 16);
        let chain = [7];
        m.allocate_prefixed(rid(1), Tokens(20), &chain).unwrap();
        m.register_prefix(rid(1), Tokens(20), &chain);
        m.free(rid(1)).unwrap();
        assert_eq!(m.cached_blocks(), 1, "zero-ref block retained");
        assert_eq!(m.pinned_blocks(), 0);
        // A re-hit resurrects it without a fresh materialization.
        let before = m.blocks_allocated();
        assert_eq!(m.allocate_prefixed(rid(2), Tokens(16), &chain)
                       .unwrap(),
                   Tokens(16));
        assert_eq!(m.blocks_allocated(), before);
        assert_eq!(m.cached_blocks(), 0);
        assert_eq!(m.pinned_blocks(), 1);
    }

    #[test]
    fn pressure_reclaims_cached_but_never_pinned() {
        // 4 blocks total. r1 pins 2 shared; r2 frees 2 cached.
        let mut m = cached_mgr(16 * 4, 16);
        m.allocate_prefixed(rid(1), Tokens(32), &[1, 2]).unwrap();
        m.register_prefix(rid(1), Tokens(32), &[1, 2]);
        m.allocate_prefixed(rid(2), Tokens(32), &[3, 4]).unwrap();
        m.register_prefix(rid(2), Tokens(32), &[3, 4]);
        m.free(rid(2)).unwrap();
        assert_eq!(m.cached_blocks(), 2);
        assert_eq!(m.free_tokens(), Tokens::ZERO);
        // r3 needs 2 fresh blocks: both come from reclaiming r2's cached
        // blocks; r1's pinned blocks are untouchable.
        assert_eq!(m.available_for(rid(3)), Tokens(32));
        m.allocate(rid(3), Tokens(32)).unwrap();
        assert_eq!(m.prefix_evictions(), 2);
        assert_eq!(m.tokens_of(rid(1)), Tokens(32));
        // Now nothing is reclaimable: a further allocation OOMs and the
        // report excludes the 4 pinned blocks.
        let err = m.allocate(rid(4), Tokens(16)).unwrap_err();
        assert_eq!(err, KvError::OutOfMemory {
            requested: Tokens(16),
            free: Tokens::ZERO,
        });
    }

    #[test]
    fn prefixed_oom_leaves_state_unchanged() {
        let mut m = cached_mgr(16 * 2, 16);
        m.allocate_prefixed(rid(1), Tokens(16), &[9]).unwrap();
        m.register_prefix(rid(1), Tokens(16), &[9]);
        // Chain hits 1 block, but the remaining 2 fresh blocks cannot
        // fit (1 free block only). The reported `free` is the
        // prefixed-path bound: 1 shared hit + 1 fresh block = 32
        // tokens, which a smaller prefixed allocation could still get.
        let err = m
            .allocate_prefixed(rid(2), Tokens(48), &[9, 10])
            .unwrap_err();
        assert_eq!(err, KvError::OutOfMemory {
            requested: Tokens(48),
            free: Tokens(32),
        });
        assert!(!m.contains(rid(2)));
        assert_eq!(m.prefix_hit_tokens(), 0);
        assert_eq!(m.pinned_blocks(), 1);
        // The reported free is exactly satisfiable on the same chain.
        assert_eq!(m.allocate_prefixed(rid(2), Tokens(32), &[9, 10])
                       .unwrap(),
                   Tokens(16));
        m.free(rid(2)).unwrap();
    }

    #[test]
    fn purge_chain_tail_drops_detached_private_blocks() {
        // Blocks registered but no longer attached to any allocation
        // (the swap-out shape): a terminal purge reclaims the private
        // tail outright while the retained prefix and pinned entries
        // survive.
        let mut m = cached_mgr(16 * 8, 16);
        m.allocate_prefixed(rid(1), Tokens(48), &[1, 2, 3]).unwrap();
        m.register_prefix(rid(1), Tokens(48), &[1, 2, 3]);
        m.free(rid(1)).unwrap(); // swap-out: all three zero-ref cached
        assert_eq!(m.cached_blocks(), 3);
        // Another request still shares the first block.
        assert_eq!(m.allocate_prefixed(rid(2), Tokens(16), &[1])
                       .unwrap(),
                   Tokens(16));
        m.purge_chain_tail(&[1, 2, 3], 1);
        assert_eq!(m.prefix_refcount(1), Some(1), "pinned by r2");
        assert!(m.prefix_refcount(2).is_none(), "tail purged");
        assert!(m.prefix_refcount(3).is_none(), "tail purged");
        assert_eq!(m.cached_blocks(), 0);
        // 8 blocks total: r2 pins one shared block, the rest are free.
        assert_eq!(m.free_tokens(), Tokens(16 * 7));
        // Idempotent and safe on absent hashes.
        m.purge_chain_tail(&[1, 2, 3], 0);
        assert_eq!(m.prefix_refcount(1), Some(1));
    }

    #[test]
    fn cache_capacity_bounds_retained_blocks() {
        let mut m = BlockManager::with_prefix_cache(Tokens(16 * 8), 16,
                                                    Some(1));
        m.allocate_prefixed(rid(1), Tokens(32), &[1, 2]).unwrap();
        m.register_prefix(rid(1), Tokens(32), &[1, 2]);
        m.free(rid(1)).unwrap();
        assert_eq!(m.cached_blocks(), 1, "capacity 1 retains one block");
        assert_eq!(m.prefix_evictions(), 1);
        assert_eq!(m.free_tokens(), Tokens(16 * 7));
    }

    #[test]
    fn terminal_free_purges_private_tail_keeps_prompt() {
        let mut m = cached_mgr(16 * 8, 16);
        // 3 full blocks: chain[0..2] = shareable prompt content,
        // chain[2] = request-private (generated) content.
        let chain = [1, 2, 3];
        m.allocate_prefixed(rid(1), Tokens(48), &chain).unwrap();
        m.register_prefix(rid(1), Tokens(48), &chain);
        m.free_discarding_private(rid(1), 2).unwrap();
        assert_eq!(m.cached_blocks(), 2, "prompt blocks stay hittable");
        assert!(m.prefix_refcount(3).is_none(), "private hash purged");
        assert_eq!(m.prefix_refcount(1), Some(0));
        assert_eq!(m.free_tokens(), Tokens(16 * 6));
    }

    #[test]
    fn terminal_free_never_purges_other_holders() {
        let mut m = cached_mgr(16 * 8, 16);
        m.allocate_prefixed(rid(1), Tokens(16), &[9]).unwrap();
        m.register_prefix(rid(1), Tokens(16), &[9]);
        assert_eq!(m.allocate_prefixed(rid(2), Tokens(16), &[9])
                       .unwrap(),
                   Tokens(16));
        // r1 finishes with retain 0: hash 9 is still pinned by r2, so
        // it must survive untouched.
        m.free_discarding_private(rid(1), 0).unwrap();
        assert_eq!(m.prefix_refcount(9), Some(1), "r2 still holds it");
        assert_eq!(m.blocks_of(rid(2)).unwrap().len(), 1);
        // Once the last holder terminally frees, it is purged outright.
        m.free_discarding_private(rid(2), 0).unwrap();
        assert!(m.prefix_refcount(9).is_none());
        assert_eq!(m.cached_blocks(), 0);
        assert_eq!(m.free_tokens(), Tokens(16 * 8));
    }

    #[test]
    fn disabled_cache_is_legacy_behavior() {
        let mut m = BlockManager::new(Tokens(64), 16);
        assert!(!m.prefix_enabled());
        // allocate_prefixed degrades to plain allocate.
        assert_eq!(m.allocate_prefixed(rid(1), Tokens(20), &[1, 2])
                       .unwrap(),
                   Tokens::ZERO);
        m.register_prefix(rid(1), Tokens(20), &[1, 2]);
        m.free(rid(1)).unwrap();
        assert_eq!(m.cached_blocks(), 0);
        assert_eq!(m.free_tokens(), Tokens(64));
    }
}
