//! CPU swap space: destination for the Swap handling strategy.
//!
//! Tracks which requests' KV contexts are parked in host memory and
//! charges the transfer-time cost model (eqn (3) charges `2 x T_swap(C)`:
//! one transfer out, one back in).

use std::collections::HashMap;

use crate::config::CostModel;
use crate::core::types::{Micros, RequestId, Tokens};

#[derive(Debug, Clone)]
pub struct SwapSpace {
    capacity: Tokens,
    parked: HashMap<RequestId, Tokens>,
    used: u64,
    /// Total tokens ever swapped out (traffic accounting for §Perf).
    pub total_swapped_out: u64,
    pub total_swapped_in: u64,
}

impl SwapSpace {
    pub fn new(capacity: Tokens) -> SwapSpace {
        SwapSpace {
            capacity,
            parked: HashMap::new(),
            used: 0,
            total_swapped_out: 0,
            total_swapped_in: 0,
        }
    }

    /// Effectively unlimited host memory (the paper's testbed has 503 GB
    /// of RAM — host capacity is never the binding constraint).
    pub fn unbounded() -> SwapSpace {
        SwapSpace::new(Tokens(u64::MAX / 2))
    }

    pub fn used(&self) -> Tokens {
        Tokens(self.used)
    }

    pub fn can_fit(&self, tokens: Tokens) -> bool {
        self.used + tokens.0 <= self.capacity.0
    }

    pub fn contains(&self, req: RequestId) -> bool {
        self.parked.contains_key(&req)
    }

    /// Tokens parked for `req` (`None` if nothing is parked).
    pub fn parked_tokens(&self, req: RequestId) -> Option<Tokens> {
        self.parked.get(&req).copied()
    }

    /// Park `tokens` of context for `req`; returns the transfer time.
    pub fn swap_out(&mut self, req: RequestId, tokens: Tokens,
                    cost: &CostModel) -> Option<Micros> {
        if !self.can_fit(tokens) || self.parked.contains_key(&req) {
            return None;
        }
        self.parked.insert(req, tokens);
        self.used += tokens.0;
        self.total_swapped_out += tokens.0;
        Some(cost.swap_time(tokens))
    }

    /// Reload `req`'s context; returns (tokens, transfer time).
    pub fn swap_in(&mut self, req: RequestId, cost: &CostModel)
                   -> Option<(Tokens, Micros)> {
        self.swap_in_with_resident(req, cost, Tokens::ZERO)
    }

    /// Reload `req`'s context when `resident` leading tokens of it are
    /// still materialized on the device (resident prefix-cache blocks):
    /// the whole parked context becomes live again, but only the
    /// non-resident remainder crosses PCIe — it alone is charged
    /// transfer time and counted as swap-in traffic. With `resident` at
    /// zero this is exactly [`SwapSpace::swap_in`]; a fully-resident
    /// restore is free (not even the transfer's base latency).
    pub fn swap_in_with_resident(&mut self, req: RequestId,
                                 cost: &CostModel, resident: Tokens)
                                 -> Option<(Tokens, Micros)> {
        let tokens = self.parked.remove(&req)?;
        self.used -= tokens.0;
        let transferred = tokens.saturating_sub(resident);
        self.total_swapped_in += transferred.0;
        Some((tokens, cost.swap_time(transferred)))
    }

    /// Drop a parked context without reloading (request aborted).
    pub fn discard(&mut self, req: RequestId) -> Option<Tokens> {
        let tokens = self.parked.remove(&req)?;
        self.used -= tokens.0;
        Some(tokens)
    }

    /// Audit self-check ([`crate::audit`]): the used gauge equals the
    /// sum of parked contexts and respects capacity. Read-only.
    pub fn check_invariants(&self) -> Result<(), String> {
        let parked_sum: u64 = self.parked.values().map(|t| t.0).sum();
        if parked_sum != self.used {
            return Err(format!(
                "swap used gauge {} != parked sum {parked_sum}",
                self.used));
        }
        if self.used > self.capacity.0 {
            return Err(format!("swap used {} exceeds capacity {}",
                               self.used, self.capacity.0));
        }
        Ok(())
    }
}

/// Direction of an in-flight KV transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferDir {
    /// Device -> host (Swap handling at an API encounter). Device blocks
    /// stay charged until the transfer drains.
    SwapOut,
    /// Host -> device (resuming a swapped request). Device blocks are
    /// charged from transfer start; decode may begin at completion.
    SwapIn,
}

/// One asynchronous host<->device KV transfer.
#[derive(Debug, Clone, Copy)]
pub struct Transfer {
    pub id: RequestId,
    pub dir: TransferDir,
    /// Context tokens being moved.
    pub tokens: Tokens,
    pub completes_at: Micros,
}

/// Tracker for swap transfers running in the background of the decode
/// loop (`ComposeConfig::async_swap`). The engine polls
/// [`TransferQueue::pop_completed`] at the top of every scheduling round
/// and treats [`TransferQueue::next_completion`] as a wake-up event when
/// idle, so transfers overlap decode instead of stalling the batch the
/// way INFERCEPT's eqn (3) charges.
#[derive(Debug, Clone, Default)]
pub struct TransferQueue {
    in_flight: Vec<Transfer>,
}

impl TransferQueue {
    pub fn new() -> TransferQueue {
        TransferQueue::default()
    }

    /// Register a transfer. A request can have at most one in flight —
    /// the engine gates admission/encounter on [`TransferQueue::contains`].
    pub fn begin(&mut self, id: RequestId, dir: TransferDir,
                 tokens: Tokens, completes_at: Micros) {
        debug_assert!(!self.contains(id),
                      "{id} already has an in-flight transfer");
        self.in_flight.push(Transfer {
            id,
            dir,
            tokens,
            completes_at,
        });
    }

    pub fn contains(&self, id: RequestId) -> bool {
        self.in_flight.iter().any(|t| t.id == id)
    }

    pub fn is_empty(&self) -> bool {
        self.in_flight.is_empty()
    }

    pub fn len(&self) -> usize {
        self.in_flight.len()
    }

    /// Earliest pending completion time (idle-jump target).
    pub fn next_completion(&self) -> Option<Micros> {
        self.in_flight.iter().map(|t| t.completes_at).min()
    }

    /// Remove and return every transfer completed by `now`, in
    /// completion-time order (ties broken by start order — the queue is
    /// insertion-ordered, and the sort is stable — keeping the
    /// discrete-event simulation deterministic).
    pub fn pop_completed(&mut self, now: Micros) -> Vec<Transfer> {
        let mut done: Vec<Transfer> = Vec::new();
        self.in_flight.retain(|t| {
            if t.completes_at <= now {
                done.push(*t);
                false
            } else {
                true
            }
        });
        done.sort_by_key(|t| t.completes_at);
        done
    }

    /// Drop a request's transfer without completing it (request dropped
    /// or preempted). Returns the cancelled transfer, if any.
    pub fn cancel(&mut self, id: RequestId) -> Option<Transfer> {
        let idx = self.in_flight.iter().position(|t| t.id == id)?;
        Some(self.in_flight.remove(idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost() -> CostModel {
        CostModel::paper_scale() // 30 us/token
    }

    #[test]
    fn swap_roundtrip() {
        let mut s = SwapSpace::new(Tokens(100));
        let t = s.swap_out(RequestId(1), Tokens(50), &cost()).unwrap();
        assert_eq!(t, Micros(2500)); // 1000 base + 50 x 30
        assert_eq!(s.used(), Tokens(50));
        assert!(s.contains(RequestId(1)));
        let (tokens, t_in) = s.swap_in(RequestId(1), &cost()).unwrap();
        assert_eq!(tokens, Tokens(50));
        assert_eq!(t_in, Micros(2500));
        assert_eq!(s.used(), Tokens::ZERO);
        assert_eq!(s.total_swapped_out, 50);
        assert_eq!(s.total_swapped_in, 50);
    }

    #[test]
    fn resident_tokens_skip_transfer_and_traffic() {
        let mut s = SwapSpace::new(Tokens(100));
        s.swap_out(RequestId(1), Tokens(50), &cost()).unwrap();
        assert_eq!(s.parked_tokens(RequestId(1)), Some(Tokens(50)));
        // 40 of 50 tokens resident: only 10 cross PCIe.
        let (tokens, t) = s
            .swap_in_with_resident(RequestId(1), &cost(), Tokens(40))
            .unwrap();
        assert_eq!(tokens, Tokens(50), "full context becomes live");
        assert_eq!(t, Micros(1300)); // 1000 base + 10 x 30
        assert_eq!(s.total_swapped_in, 10);
        assert_eq!(s.parked_tokens(RequestId(1)), None);
        // Fully resident: free, no base latency either.
        s.swap_out(RequestId(2), Tokens(20), &cost()).unwrap();
        let (tokens, t) = s
            .swap_in_with_resident(RequestId(2), &cost(), Tokens(20))
            .unwrap();
        assert_eq!((tokens, t), (Tokens(20), Micros::ZERO));
        assert_eq!(s.total_swapped_in, 10);
    }

    #[test]
    fn capacity_enforced() {
        let mut s = SwapSpace::new(Tokens(60));
        assert!(s.swap_out(RequestId(1), Tokens(50), &cost()).is_some());
        assert!(s.swap_out(RequestId(2), Tokens(20), &cost()).is_none());
        assert!(s.swap_out(RequestId(2), Tokens(10), &cost()).is_some());
    }

    #[test]
    fn double_swap_out_rejected() {
        let mut s = SwapSpace::unbounded();
        assert!(s.swap_out(RequestId(1), Tokens(10), &cost()).is_some());
        assert!(s.swap_out(RequestId(1), Tokens(10), &cost()).is_none());
    }

    #[test]
    fn swap_in_unknown_is_none() {
        let mut s = SwapSpace::unbounded();
        assert!(s.swap_in(RequestId(7), &cost()).is_none());
    }

    #[test]
    fn discard_drops_without_traffic() {
        let mut s = SwapSpace::unbounded();
        s.swap_out(RequestId(1), Tokens(25), &cost()).unwrap();
        assert_eq!(s.discard(RequestId(1)), Some(Tokens(25)));
        assert_eq!(s.total_swapped_in, 0);
        assert_eq!(s.used(), Tokens::ZERO);
    }

    #[test]
    fn transfer_queue_completion_order() {
        let mut q = TransferQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.next_completion(), None);
        q.begin(RequestId(1), TransferDir::SwapOut, Tokens(10),
                Micros(300));
        q.begin(RequestId(2), TransferDir::SwapIn, Tokens(20),
                Micros(100));
        q.begin(RequestId(3), TransferDir::SwapOut, Tokens(5),
                Micros(200));
        assert_eq!(q.len(), 3);
        assert_eq!(q.next_completion(), Some(Micros(100)));
        assert!(q.contains(RequestId(2)));

        let done = q.pop_completed(Micros(250));
        let ids: Vec<RequestId> = done.iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![RequestId(2), RequestId(3)]);
        assert_eq!(done[0].tokens, Tokens(20));
        assert_eq!(q.len(), 1);
        assert_eq!(q.next_completion(), Some(Micros(300)));

        // Nothing completes before its time.
        assert!(q.pop_completed(Micros(299)).is_empty());
        assert_eq!(q.pop_completed(Micros(300)).len(), 1);
        assert!(q.is_empty());
    }

    #[test]
    fn transfer_queue_cancel() {
        let mut q = TransferQueue::new();
        q.begin(RequestId(7), TransferDir::SwapIn, Tokens(8), Micros(50));
        assert!(q.cancel(RequestId(9)).is_none());
        let t = q.cancel(RequestId(7)).unwrap();
        assert_eq!(t.dir, TransferDir::SwapIn);
        assert_eq!(t.tokens, Tokens(8));
        assert!(q.is_empty());
        assert!(q.pop_completed(Micros(1000)).is_empty());
    }
}
