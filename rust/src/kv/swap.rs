//! CPU swap space: destination for the Swap handling strategy.
//!
//! Tracks which requests' KV contexts are parked in host memory and
//! charges the transfer-time cost model (eqn (3) charges `2 x T_swap(C)`:
//! one transfer out, one back in).

use std::collections::HashMap;

use crate::config::CostModel;
use crate::core::types::{Micros, RequestId, Tokens};

#[derive(Debug, Clone)]
pub struct SwapSpace {
    capacity: Tokens,
    parked: HashMap<RequestId, Tokens>,
    used: u64,
    /// Total tokens ever swapped out (traffic accounting for §Perf).
    pub total_swapped_out: u64,
    pub total_swapped_in: u64,
}

impl SwapSpace {
    pub fn new(capacity: Tokens) -> SwapSpace {
        SwapSpace {
            capacity,
            parked: HashMap::new(),
            used: 0,
            total_swapped_out: 0,
            total_swapped_in: 0,
        }
    }

    /// Effectively unlimited host memory (the paper's testbed has 503 GB
    /// of RAM — host capacity is never the binding constraint).
    pub fn unbounded() -> SwapSpace {
        SwapSpace::new(Tokens(u64::MAX / 2))
    }

    pub fn used(&self) -> Tokens {
        Tokens(self.used)
    }

    pub fn can_fit(&self, tokens: Tokens) -> bool {
        self.used + tokens.0 <= self.capacity.0
    }

    pub fn contains(&self, req: RequestId) -> bool {
        self.parked.contains_key(&req)
    }

    /// Park `tokens` of context for `req`; returns the transfer time.
    pub fn swap_out(&mut self, req: RequestId, tokens: Tokens,
                    cost: &CostModel) -> Option<Micros> {
        if !self.can_fit(tokens) || self.parked.contains_key(&req) {
            return None;
        }
        self.parked.insert(req, tokens);
        self.used += tokens.0;
        self.total_swapped_out += tokens.0;
        Some(cost.swap_time(tokens))
    }

    /// Reload `req`'s context; returns (tokens, transfer time).
    pub fn swap_in(&mut self, req: RequestId, cost: &CostModel)
                   -> Option<(Tokens, Micros)> {
        let tokens = self.parked.remove(&req)?;
        self.used -= tokens.0;
        self.total_swapped_in += tokens.0;
        Some((tokens, cost.swap_time(tokens)))
    }

    /// Drop a parked context without reloading (request aborted).
    pub fn discard(&mut self, req: RequestId) -> Option<Tokens> {
        let tokens = self.parked.remove(&req)?;
        self.used -= tokens.0;
        Some(tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost() -> CostModel {
        CostModel::paper_scale() // 30 us/token
    }

    #[test]
    fn swap_roundtrip() {
        let mut s = SwapSpace::new(Tokens(100));
        let t = s.swap_out(RequestId(1), Tokens(50), &cost()).unwrap();
        assert_eq!(t, Micros(2500)); // 1000 base + 50 x 30
        assert_eq!(s.used(), Tokens(50));
        assert!(s.contains(RequestId(1)));
        let (tokens, t_in) = s.swap_in(RequestId(1), &cost()).unwrap();
        assert_eq!(tokens, Tokens(50));
        assert_eq!(t_in, Micros(2500));
        assert_eq!(s.used(), Tokens::ZERO);
        assert_eq!(s.total_swapped_out, 50);
        assert_eq!(s.total_swapped_in, 50);
    }

    #[test]
    fn capacity_enforced() {
        let mut s = SwapSpace::new(Tokens(60));
        assert!(s.swap_out(RequestId(1), Tokens(50), &cost()).is_some());
        assert!(s.swap_out(RequestId(2), Tokens(20), &cost()).is_none());
        assert!(s.swap_out(RequestId(2), Tokens(10), &cost()).is_some());
    }

    #[test]
    fn double_swap_out_rejected() {
        let mut s = SwapSpace::unbounded();
        assert!(s.swap_out(RequestId(1), Tokens(10), &cost()).is_some());
        assert!(s.swap_out(RequestId(1), Tokens(10), &cost()).is_none());
    }

    #[test]
    fn swap_in_unknown_is_none() {
        let mut s = SwapSpace::unbounded();
        assert!(s.swap_in(RequestId(7), &cost()).is_none());
    }

    #[test]
    fn discard_drops_without_traffic() {
        let mut s = SwapSpace::unbounded();
        s.swap_out(RequestId(1), Tokens(25), &cost()).unwrap();
        assert_eq!(s.discard(RequestId(1)), Some(Tokens(25)));
        assert_eq!(s.total_swapped_in, 0);
        assert_eq!(s.used(), Tokens::ZERO);
    }
}
