//! Hash-consed, refcounted prefix cache for the KV [`BlockManager`]
//! (vLLM "automatic prefix caching" / SGLang RadixAttention, adapted to
//! this stack's token-slot accounting).
//!
//! The unit of sharing is one **full block** of context. Every full
//! block of a request's materialized context gets a *chain hash*: a
//! rolling hash over all token content from position 0 through the end
//! of that block, so equal hashes imply equal full prefixes (the
//! hash-consing property — block `i` can only be shared by requests
//! whose entire first `i+1` blocks of content agree). The cache maps
//! chain hashes to physical blocks with a refcount:
//!
//! - **hit**: `BlockManager::allocate_prefixed` walks a request's chain
//!   and pins (refcount++) every already-materialized leading block; the
//!   request skips prefilling those tokens entirely.
//! - **release**: freeing a request decrements refcounts; blocks reaching
//!   zero are *retained* in an LRU of reclaimable cached blocks instead
//!   of returning to the free list, so later requests (or the same
//!   request's post-Discard recompute) can re-hit them.
//! - **reclaim**: under memory pressure the manager evicts zero-ref
//!   cached blocks (oldest first) back to the free list before reporting
//!   OOM. Pinned (refcount > 0) blocks are never evicted.
//!
//! A partial tail block is never shared: divergence inside a block is
//! resolved copy-on-write style by materializing the tail tokens into a
//! fresh private block while the full-block prefix stays shared.
//!
//! **Content model.** The simulator has no real token ids, so token
//! content is synthesized positionally: prompt positions hash the prompt
//! *bytes* (equal prompt text ⇒ equal chains; a shared leading substring
//! shares proportionally many blocks), positions past the prompt text
//! hash an explicit pad marker (so "AB" padded to 10 tokens never
//! collides with "ABB"), and generated/API-response positions hash
//! `(request id, position)` — private to the request, which is exactly
//! what makes its own discard-recompute re-hit the cache without ever
//! aliasing another request's generations. Content-less synthetic
//! prompts (empty text) are likewise keyed per-request rather than
//! inventing cross-request sharing that the workload never specified.
//!
//! [`BlockManager`]: super::block_manager::BlockManager

use std::collections::{HashMap, VecDeque};

use super::block_manager::BlockId;
use crate::core::request::RequestSpec;
use crate::core::types::Tokens;

/// Chain hash of one full block of context (position 0 through the end
/// of the block), FNV-1a over the synthesized token content.
pub type BlockHash = u64;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Marker mixed for prompt positions past the end of the prompt text
/// (distinct from any byte value).
const PAD_MARKER: u64 = 0x100;
/// Marker mixed for per-request private content (generated tokens, API
/// responses, content-less synthetic prompts).
const PRIVATE_MARKER: u64 = 0x200;

fn mix(h: &mut u64, value: u64) {
    for byte in value.to_le_bytes() {
        *h = (*h ^ u64::from(byte)).wrapping_mul(FNV_PRIME);
    }
}

/// Mix one context position into the rolling chain hash: a prompt byte,
/// the pad marker for prompt positions past the prompt text, or the
/// per-request private key for generated/API content.
fn mix_position(h: &mut u64, spec: &RequestSpec, bytes: &[u8], p: u64) {
    if p < spec.prompt_tokens.0 && !bytes.is_empty() {
        if (p as usize) < bytes.len() {
            // lamps-lint: allow(panic) p is range-checked against bytes.len() just above
            mix(h, u64::from(bytes[p as usize]));
        } else {
            mix(h, PAD_MARKER);
        }
    } else {
        mix(h, PRIVATE_MARKER);
        mix(h, spec.id.0);
        mix(h, p);
    }
}

/// Chain hashes for every full block of the first `upto` tokens of
/// `spec`'s context (`floor(upto / block_size)` entries). Positions
/// beyond the prompt are keyed per-request (see the module docs), so a
/// chain is valid for any `upto` not exceeding the request's
/// materialized context.
pub fn content_chain(spec: &RequestSpec, block_size: u64, upto: Tokens)
                     -> Vec<BlockHash> {
    assert!(block_size > 0, "block_size must be positive");
    let mut chain = Vec::with_capacity((upto.0 / block_size) as usize);
    extend_content_chain(spec, block_size, &mut chain, upto);
    chain
}

/// Extend an existing chain (a prefix of `spec`'s full chain at this
/// `block_size`) in place up to `floor(upto / block_size)` entries
/// without rehashing the positions it already covers. Sound because the
/// rolling hash continues from the value pushed at each block boundary:
/// the chain's last entry *is* the rolling state at the next block's
/// first position. A chain longer than `upto` needs is left untouched —
/// chains are prefix-consistent across `upto` values.
pub fn extend_content_chain(spec: &RequestSpec, block_size: u64,
                            chain: &mut Vec<BlockHash>, upto: Tokens) {
    assert!(block_size > 0, "block_size must be positive");
    let full_blocks = upto.0 / block_size;
    if (chain.len() as u64) >= full_blocks {
        return;
    }
    let mut h = chain.last().copied().unwrap_or_else(|| {
        let mut h = FNV_OFFSET;
        mix(&mut h, block_size);
        h
    });
    let bytes = spec.prompt.as_bytes();
    for p in (chain.len() as u64 * block_size)..full_blocks * block_size {
        mix_position(&mut h, spec, bytes, p);
        if (p + 1) % block_size == 0 {
            chain.push(h);
        }
    }
}

/// One resident-set change of a replica-local prefix cache, journaled
/// (when armed by [`PrefixCache::enable_journal`]) for a fleet-level
/// observer: the cross-replica
/// [`SharedPrefixIndex`](crate::cluster::SharedPrefixIndex) mirrors
/// each replica's resident hashes from these deltas. Pins and releases
/// are *not* deltas — a block stays resident (hittable) across its
/// whole refcount lifecycle; only registration and physical removal
/// (pressure/capacity eviction, purge) change residency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefixDelta {
    /// `hash` became resident: a freshly materialized full block was
    /// registered under it.
    Registered(BlockHash),
    /// `hash` left the cache: its physical block was evicted under
    /// pressure/capacity or purged as request-private garbage.
    Removed(BlockHash),
}

#[derive(Debug, Clone, Copy)]
struct CachedBlock {
    block: BlockId,
    /// Live allocations holding this block (0 = reclaimable, on the LRU).
    refcount: u32,
    /// Stamp of this block's live LRU entry — meaningful only while
    /// `refcount == 0`. A deque entry whose stamp disagrees is a
    /// tombstone left behind by resurrection or purge.
    lru_stamp: u64,
}

/// The hash → physical-block map plus the LRU of zero-ref cached blocks.
/// Owned by the [`BlockManager`]; all physical-block bookkeeping (free
/// lists, token accounting) stays there.
///
/// [`BlockManager`]: super::block_manager::BlockManager
#[derive(Debug, Clone, Default)]
pub struct PrefixCache {
    map: HashMap<BlockHash, CachedBlock>,
    /// Zero-ref eviction queue, oldest (first to evict) at the front.
    /// Entries are `(hash, stamp)` and lazily invalidated: one is live
    /// iff the map still holds `hash` at refcount 0 with the same stamp.
    /// Resurrection ([`PrefixCache::pin`]) used to scan-remove its entry
    /// here — O(zero-ref blocks) per pin; tombstoning instead makes pin
    /// O(1), with stale entries skipped (amortized O(1)) whenever the
    /// queue is popped and swept out when they outnumber live ones.
    lru: VecDeque<(BlockHash, u64)>,
    /// Count of *live* entries in `lru` (the zero-ref gauge).
    zero_ref: u64,
    /// Monotonic stamp source for LRU entries.
    next_stamp: u64,
    /// Maximum zero-ref blocks retained after frees; `None` keeps every
    /// reclaimable block until memory pressure evicts it.
    capacity: Option<u64>,
    /// Tokens served from cache hits instead of being prefilled.
    hit_tokens: u64,
    /// Zero-ref cached blocks evicted (capacity or memory pressure).
    evictions: u64,
    /// Resident-set delta journal for a fleet-level observer (see
    /// [`PrefixDelta`]); records only while `journal_on`. Purely
    /// observational — nothing in the cache reads it back.
    journal: Vec<PrefixDelta>,
    /// Armed by [`PrefixCache::enable_journal`] (a `ReplicaSet` with
    /// `--shared-prefix` drains the journal after every replica step).
    journal_on: bool,
}

impl PrefixCache {
    pub fn new(capacity: Option<u64>) -> PrefixCache {
        PrefixCache {
            capacity,
            ..PrefixCache::default()
        }
    }

    pub fn hit_tokens(&self) -> u64 {
        self.hit_tokens
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Zero-ref cached blocks (reclaimable under pressure).
    pub fn zero_ref(&self) -> u64 {
        self.zero_ref
    }

    pub fn contains(&self, hash: BlockHash) -> bool {
        self.map.contains_key(&hash)
    }

    /// Is the cached block for `hash` held by at least one allocation?
    pub fn is_pinned(&self, hash: BlockHash) -> bool {
        self.map.get(&hash).is_some_and(|c| c.refcount > 0)
    }

    /// Refcount of `hash` (0 for zero-ref cached, `None` if absent).
    pub fn refcount_of(&self, hash: BlockHash) -> Option<u32> {
        self.map.get(&hash).map(|c| c.refcount)
    }

    /// Canonical physical block for `hash` (`None` when absent) —
    /// read-only introspection for the audit layer.
    pub fn block_of(&self, hash: BlockHash) -> Option<BlockId> {
        self.map.get(&hash).map(|c| c.block)
    }

    /// Audit self-check ([`crate::audit`]): the zero-ref gauge matches
    /// the map, live LRU entries mirror exactly the zero-ref
    /// population, and no two hashes alias one physical block.
    /// Read-only.
    pub fn check_invariants(&self) -> Result<(), String> {
        let zero_in_map =
            self.map.values().filter(|c| c.refcount == 0).count() as u64;
        if zero_in_map != self.zero_ref {
            return Err(format!(
                "zero-ref gauge {} != {zero_in_map} zero-ref map \
                 entries",
                self.zero_ref));
        }
        let live_in_lru = self
            .lru
            .iter()
            .filter(|&&(h, s)| {
                PrefixCache::lru_entry_live(&self.map, h, s)
            })
            .count() as u64;
        if live_in_lru != self.zero_ref {
            return Err(format!(
                "{live_in_lru} live LRU entries for {} zero-ref \
                 blocks",
                self.zero_ref));
        }
        let mut blocks: Vec<BlockId> =
            self.map.values().map(|c| c.block).collect();
        blocks.sort_unstable();
        blocks.dedup();
        if blocks.len() != self.map.len() {
            return Err("two cached hashes alias one physical block"
                .to_string());
        }
        Ok(())
    }

    pub(super) fn note_hit_tokens(&mut self, tokens: u64) {
        self.hit_tokens += tokens;
    }

    /// Start journaling resident-set deltas (see [`PrefixDelta`]).
    pub(super) fn enable_journal(&mut self) {
        self.journal_on = true;
    }

    /// Take the journaled deltas accumulated since the last drain.
    pub(super) fn drain_journal(&mut self) -> Vec<PrefixDelta> {
        std::mem::take(&mut self.journal)
    }

    fn note_delta(&mut self, delta: PrefixDelta) {
        if self.journal_on {
            self.journal.push(delta);
        }
    }

    /// Every hash currently resident (any refcount), sorted — the
    /// ground truth the fleet-level index must stay a subset of.
    pub fn resident_hashes(&self) -> Vec<BlockHash> {
        let mut hashes: Vec<BlockHash> = self.map.keys().copied().collect();
        hashes.sort_unstable();
        hashes
    }

    /// Is `(hash, stamp)` a live LRU entry (vs a tombstone)?
    fn lru_entry_live(map: &HashMap<BlockHash, CachedBlock>,
                      hash: BlockHash, stamp: u64) -> bool {
        map.get(&hash)
            .is_some_and(|c| c.refcount == 0 && c.lru_stamp == stamp)
    }

    /// Pin the cached block for `hash` (refcount++), resurrecting it
    /// from the LRU if it was zero-ref. `None` if the hash is absent.
    ///
    /// O(1): resurrection only bumps the refcount, turning the block's
    /// deque entry into a tombstone that later pops skip (the slot-index
    /// alternative to the old O(zero-ref) scan).
    pub(super) fn pin(&mut self, hash: BlockHash) -> Option<BlockId> {
        let cached = self.map.get_mut(&hash)?;
        if cached.refcount == 0 {
            debug_assert!(self.zero_ref > 0, "zero-ref gauge underflow");
            self.zero_ref -= 1;
        }
        cached.refcount += 1;
        Some(cached.block)
    }

    /// Register a freshly materialized block under `hash` with refcount
    /// 1. Returns false (and leaves the block private) when the hash is
    /// already cached — duplicate content materialized concurrently
    /// keeps exactly one canonical physical block.
    pub(super) fn register(&mut self, hash: BlockHash, block: BlockId)
                           -> bool {
        if self.map.contains_key(&hash) {
            return false;
        }
        self.map.insert(hash, CachedBlock {
            block,
            refcount: 1,
            lru_stamp: 0,
        });
        self.note_delta(PrefixDelta::Registered(hash));
        true
    }

    /// Drop one holder of `hash`; at zero refs the block is retained on
    /// the LRU (reclaimable), not freed.
    pub(super) fn release(&mut self, hash: BlockHash) {
        let stamp = self.next_stamp;
        let cached = self
            .map
            .get_mut(&hash)
            // lamps-lint: allow(panic) release pairs a pin — the auditor checks refcounts
            .expect("release of unregistered prefix block");
        assert!(cached.refcount > 0, "prefix refcount underflow");
        cached.refcount -= 1;
        if cached.refcount == 0 {
            self.next_stamp += 1;
            cached.lru_stamp = stamp;
            self.lru.push_back((hash, stamp));
            self.zero_ref += 1;
            self.compact_if_stale();
        }
    }

    /// Sweep tombstones once they dominate the deque, bounding its
    /// length to O(zero-ref) without breaking amortized-O(1) release.
    fn compact_if_stale(&mut self) {
        if (self.lru.len() as u64) <= 32 + 2 * self.zero_ref {
            return;
        }
        let map = &self.map;
        self.lru
            .retain(|&(h, s)| PrefixCache::lru_entry_live(map, h, s));
        debug_assert_eq!(self.lru.len() as u64, self.zero_ref);
    }

    /// Remove `hash` from the cache if (and only if) it is zero-ref,
    /// returning its physical block. Disposal hook for request-private
    /// content that can never be re-hit once its request finished — a
    /// pinned hash (another live holder) is left untouched.
    pub(super) fn purge_zero_ref(&mut self, hash: BlockHash)
                                 -> Option<BlockId> {
        if self.refcount_of(hash) != Some(0) {
            return None;
        }
        // The deque entry becomes a tombstone (the map lookup fails).
        // lamps-lint: allow(panic) the refcount-zero branch checked presence above
        let cached = self.map.remove(&hash).expect("checked present");
        debug_assert!(self.zero_ref > 0, "zero-ref gauge underflow");
        self.zero_ref -= 1;
        self.note_delta(PrefixDelta::Removed(hash));
        Some(cached.block)
    }

    /// Evict the oldest zero-ref cached block, returning its physical
    /// block to the caller's free list. Skips tombstones (amortized
    /// O(1): each deque entry is popped at most once).
    pub(super) fn reclaim_one(&mut self) -> Option<BlockId> {
        while let Some((hash, stamp)) = self.lru.pop_front() {
            if !PrefixCache::lru_entry_live(&self.map, hash, stamp) {
                continue; // tombstone from a resurrection or purge
            }
            let cached =
                // lamps-lint: allow(panic) lru_entry_live just confirmed the map entry
                self.map.remove(&hash).expect("live entry is mapped");
            debug_assert_eq!(cached.refcount, 0, "LRU held a pinned block");
            self.zero_ref -= 1;
            self.evictions += 1;
            self.note_delta(PrefixDelta::Removed(hash));
            return Some(cached.block);
        }
        None
    }

    /// Evict zero-ref blocks beyond the configured retention capacity
    /// (oldest first), returning the freed physical blocks.
    pub(super) fn evict_over_capacity(&mut self) -> Vec<BlockId> {
        let Some(cap) = self.capacity else {
            return Vec::new();
        };
        let mut freed = Vec::new();
        while self.zero_ref() > cap {
            // lamps-lint: allow(panic) the zero_ref gauge counts exactly the reclaimable entries
            freed.push(self.reclaim_one().expect("zero_ref > 0"));
        }
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::types::{Micros, RequestId};

    fn spec(id: u64, prompt: &str, prompt_tokens: u64) -> RequestSpec {
        RequestSpec {
            id: RequestId(id),
            arrival: Micros::ZERO,
            prompt: prompt.to_string(),
            prompt_tokens: Tokens(prompt_tokens),
            api_calls: vec![],
            final_decode: Tokens(1),
        }
    }

    #[test]
    fn equal_prompts_share_whole_chain() {
        let a = content_chain(&spec(1, "system: be nice", 15), 4,
                              Tokens(12));
        let b = content_chain(&spec(2, "system: be nice", 15), 4,
                              Tokens(12));
        assert_eq!(a.len(), 3);
        assert_eq!(a, b, "identical prompt content must hash identically");
    }

    #[test]
    fn shared_text_prefix_shares_leading_blocks_only() {
        let a = content_chain(&spec(1, "SHAREDSHAREDxxxx", 16), 4,
                              Tokens(16));
        let b = content_chain(&spec(2, "SHAREDSHAREDyyyy", 16), 4,
                              Tokens(16));
        assert_eq!(a[..3], b[..3], "12 shared chars = 3 shared blocks");
        assert_ne!(a[3], b[3], "divergent block must not collide");
    }

    #[test]
    fn padding_does_not_alias_longer_prompts() {
        // "AB" padded to 12 tokens vs "ABB...": chains diverge at the
        // first padded position.
        let a = content_chain(&spec(1, "AB", 12), 4, Tokens(12));
        let b = content_chain(&spec(2, "ABBBBBBBBBBB", 12), 4, Tokens(12));
        assert_ne!(a[0], b[0]);
    }

    #[test]
    fn contentless_prompts_are_private_per_request() {
        let a = content_chain(&spec(1, "", 8), 4, Tokens(8));
        let b = content_chain(&spec(2, "", 8), 4, Tokens(8));
        assert_ne!(a, b, "synthetic prompts must never cross-share");
        // ...but are stable for the same request (self-recompute hits).
        let a2 = content_chain(&spec(1, "", 8), 4, Tokens(8));
        assert_eq!(a, a2);
    }

    #[test]
    fn generated_region_is_private_and_stable() {
        let s = spec(7, "abcdefgh", 8);
        // Chain over prompt (8) + 8 generated tokens.
        let c1 = content_chain(&s, 4, Tokens(16));
        let c2 = content_chain(&s, 4, Tokens(16));
        assert_eq!(c1, c2);
        assert_eq!(c1.len(), 4);
        // Prompt blocks agree with a prompt-only chain (prefix property).
        let prompt_only = content_chain(&s, 4, Tokens(8));
        assert_eq!(c1[..2], prompt_only[..]);
    }

    #[test]
    fn chain_length_is_full_blocks_only() {
        let s = spec(1, "abcdefghij", 10);
        assert_eq!(content_chain(&s, 4, Tokens(10)).len(), 2);
        assert_eq!(content_chain(&s, 4, Tokens(3)).len(), 0);
        assert_eq!(content_chain(&s, 4, Tokens(0)).len(), 0);
    }

    #[test]
    fn extend_matches_from_scratch_at_every_cut() {
        // Resuming the rolling hash from a shorter chain must equal the
        // from-scratch chain at every extension point, across the
        // prompt → pad → private-region transitions.
        let s = spec(9, "abcdef", 10);
        let full = content_chain(&s, 4, Tokens(24));
        for cut in 0..=24u64 {
            let mut chain = content_chain(&s, 4, Tokens(cut));
            extend_content_chain(&s, 4, &mut chain, Tokens(24));
            assert_eq!(chain, full, "cut at {cut} tokens diverged");
        }
    }

    #[test]
    fn extend_never_truncates_a_longer_chain() {
        let s = spec(3, "abcdefgh", 8);
        let mut chain = content_chain(&s, 4, Tokens(16));
        let before = chain.clone();
        extend_content_chain(&s, 4, &mut chain, Tokens(4));
        assert_eq!(chain, before, "shorter upto must be a no-op");
    }

    #[test]
    fn pin_release_reclaim_lifecycle() {
        let mut c = PrefixCache::new(None);
        assert!(c.register(42, 5));
        assert!(!c.register(42, 6), "duplicate hash keeps one block");
        assert_eq!(c.refcount_of(42), Some(1));
        assert_eq!(c.pin(42), Some(5));
        assert_eq!(c.refcount_of(42), Some(2));
        c.release(42);
        c.release(42);
        assert_eq!(c.refcount_of(42), Some(0));
        assert_eq!(c.zero_ref(), 1);
        // Resurrection removes it from the LRU.
        assert_eq!(c.pin(42), Some(5));
        assert_eq!(c.zero_ref(), 0);
        c.release(42);
        assert_eq!(c.reclaim_one(), Some(5));
        assert_eq!(c.evictions(), 1);
        assert!(!c.contains(42));
        assert_eq!(c.reclaim_one(), None);
    }

    #[test]
    fn resurrection_preserves_eviction_order() {
        // Pin/release cycles must leave the LRU order exactly as the
        // scan-based implementation did: a resurrected block re-enters
        // at the tail when it is next released.
        let mut c = PrefixCache::new(None);
        c.register(1, 10);
        c.register(2, 20);
        c.register(3, 30);
        c.release(1);
        c.release(2);
        c.release(3); // LRU: 1, 2, 3
        assert_eq!(c.pin(2), Some(20), "resurrect the middle entry");
        assert_eq!(c.zero_ref(), 2);
        c.release(2); // LRU: 1, 3, 2
        assert_eq!(c.reclaim_one(), Some(10));
        assert_eq!(c.reclaim_one(), Some(30));
        assert_eq!(c.reclaim_one(), Some(20));
        assert_eq!(c.reclaim_one(), None);
        assert_eq!(c.evictions(), 3);
        assert_eq!(c.zero_ref(), 0);
    }

    #[test]
    fn tombstones_never_distort_gauge_or_order() {
        // Heavy pin/release/purge churn: the zero-ref gauge, capacity
        // eviction, and reclaim order must all ignore stale deque
        // entries (and the deque itself must stay bounded).
        let mut c = PrefixCache::new(None);
        c.register(7, 70);
        c.register(8, 80);
        for _ in 0..200 {
            c.release(7);
            assert_eq!(c.pin(7), Some(70));
        }
        assert_eq!(c.zero_ref(), 0);
        c.release(8);
        c.release(7); // LRU: 8, 7
        assert_eq!(c.zero_ref(), 2);
        assert_eq!(c.purge_zero_ref(8), Some(80));
        assert_eq!(c.zero_ref(), 1);
        assert_eq!(c.reclaim_one(), Some(70), "purged 8 is a tombstone");
        assert_eq!(c.reclaim_one(), None);
    }

    #[test]
    fn journal_records_residency_changes_only() {
        let mut c = PrefixCache::new(None);
        c.register(1, 10);
        assert!(c.drain_journal().is_empty(), "journal off by default");
        c.enable_journal();
        c.register(2, 20);
        assert_eq!(c.pin(2), Some(20), "pins are not residency changes");
        c.release(2);
        c.release(2);
        assert_eq!(c.purge_zero_ref(2), Some(20));
        c.release(1);
        assert_eq!(c.reclaim_one(), Some(10));
        assert_eq!(c.drain_journal(), vec![
            PrefixDelta::Registered(2),
            PrefixDelta::Removed(2),
            PrefixDelta::Removed(1),
        ]);
        assert!(c.drain_journal().is_empty(), "drain empties the journal");
        assert!(c.resident_hashes().is_empty());
    }

    #[test]
    fn resident_hashes_are_sorted_ground_truth() {
        let mut c = PrefixCache::new(None);
        c.register(9, 90);
        c.register(3, 30);
        c.register(7, 70);
        c.release(7);
        assert_eq!(c.resident_hashes(), vec![3, 7, 9],
                   "zero-ref blocks are still resident");
        c.purge_zero_ref(7);
        assert_eq!(c.resident_hashes(), vec![3, 9]);
    }

    #[test]
    fn capacity_evicts_oldest_zero_ref() {
        let mut c = PrefixCache::new(Some(1));
        c.register(1, 10);
        c.register(2, 20);
        c.release(1);
        assert!(c.evict_over_capacity().is_empty(), "1 zero-ref <= cap 1");
        c.release(2);
        assert_eq!(c.evict_over_capacity(), vec![10], "oldest goes first");
        assert!(c.contains(2));
        assert_eq!(c.evictions(), 1);
    }
}
