//! Paged KV-cache accounting (vLLM-style block manager), hash-consed
//! refcounted prefix caching, and CPU swap space.

pub mod block_manager;
pub mod prefix;
pub mod swap;

pub use block_manager::{BlockManager, KvError};
pub use prefix::{content_chain, BlockHash, PrefixCache, PrefixDelta};
pub use swap::{SwapSpace, Transfer, TransferDir, TransferQueue};
