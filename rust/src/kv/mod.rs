//! Paged KV-cache accounting (vLLM-style block manager) + CPU swap space.

pub mod block_manager;
pub mod swap;

pub use block_manager::{BlockManager, KvError};
pub use swap::{SwapSpace, Transfer, TransferDir, TransferQueue};
