//! Per-rule fixtures for `lamps-lint`: one known-violating and one
//! clean snippet per rule, the `allow` escape syntax (good and
//! malformed), the test-code exemption, and a scan of the on-disk
//! fixture corpus under `rust/lint-fixtures/` proving every rule
//! catches its seeded violation there.

use std::path::Path;

use super::{scan_source, scan_tree, Violation, RULES};

fn rules_hit(violations: &[Violation]) -> Vec<&'static str> {
    violations.iter().map(|v| v.rule).collect()
}

// -- wire-format -------------------------------------------------------

#[test]
fn wire_format_flags_spliced_json_in_server() {
    let src = r#"
pub fn frame(id: u64) -> String {
    format!("{{\"type\":\"error\",\"id\":{id}}}")
}
"#;
    let v = scan_source("server/wire.rs", src);
    assert!(rules_hit(&v).contains(&"wire-format"), "{v:?}");
}

#[test]
fn wire_format_ignores_plain_messages_and_other_dirs() {
    let clean = r#"
pub fn msg(e: &str) -> String {
    format!("bad request: {e}")
}
"#;
    assert!(scan_source("server/wire.rs", clean).is_empty());
    let spliced = r#"
pub fn frame(id: u64) -> String {
    format!("{{\"type\":\"error\",\"id\":{id}}}")
}
"#;
    // Outside server/ the wire rule does not apply.
    assert!(scan_source("util/fmt.rs", spliced).is_empty());
}

#[test]
fn wire_format_flags_push_str_and_raw_strings() {
    let src = r##"
pub fn frame(out: &mut String) {
    out.push_str(r#"{"type":"error"}"#);
}
"##;
    let v = scan_source("server/wire.rs", src);
    assert!(rules_hit(&v).contains(&"wire-format"), "{v:?}");
}

// -- wire-hot-path -----------------------------------------------------

#[test]
fn wire_hot_path_flags_json_round_trips_in_server() {
    let src = r#"
pub fn dispatch(line: &str) -> String {
    let v = json::parse(line).unwrap_or(json::Value::Null);
    json::write(&v)
}
"#;
    let v = scan_source("server/conn.rs", src);
    let hits = rules_hit(&v);
    assert_eq!(hits.iter().filter(|r| **r == "wire-hot-path").count(),
               2, "{v:?}");
}

#[test]
fn wire_hot_path_spares_constructors_other_dirs_and_tests() {
    // The typed constructors stay legal in server/ (cold paths).
    let constructors = r#"
pub fn report(id: u64) -> json::Value {
    json::obj(vec![("id", json::num(id as f64)), ("ok", json::s("y"))])
}
"#;
    assert!(scan_source("server/report.rs", constructors).is_empty());
    // Outside server/ the rule does not apply.
    let elsewhere = r#"
pub fn load(text: &str) -> Result<json::Value, String> {
    json::parse(text)
}
"#;
    assert!(scan_source("bench/baseline.rs", elsewhere).is_empty());
    // Test items are stripped before the rule runs.
    let test_only = r#"
#[cfg(test)]
mod tests {
    #[test]
    fn round_trip() {
        let v = json::parse("{}").unwrap();
        assert_eq!(json::write(&v), "{}");
    }
}
"#;
    assert!(scan_source("server/conn.rs", test_only).is_empty());
}

// -- panic -------------------------------------------------------------

#[test]
fn panic_rule_flags_unwrap_expect_macros_and_indexing() {
    let src = r#"
pub fn f(xs: &[u64], m: Option<u64>) -> u64 {
    let a = m.unwrap();
    let b = m.expect("present");
    if xs.is_empty() {
        panic!("empty");
    }
    a + b + xs[0]
}
"#;
    let v = scan_source("engine/f.rs", src);
    let hits = rules_hit(&v);
    assert_eq!(hits.iter().filter(|r| **r == "panic").count(), 4,
               "{v:?}");
}

#[test]
fn panic_rule_scoped_to_scheduler_dirs_and_spares_non_index_brackets() {
    let src = r#"
pub fn f(m: Option<u64>) -> u64 {
    m.unwrap()
}
"#;
    // util/ is outside the panic rule's scope.
    assert!(scan_source("util/f.rs", src).is_empty());
    let clean = r#"
pub fn g(pair: (u64, u64), xs: &[u64]) -> u64 {
    let [_a, _b] = [pair.0, pair.1];
    let v = vec![1u64, 2];
    xs.first().copied().unwrap_or(0) + v.len() as u64
}
"#;
    assert!(scan_source("kv/g.rs", clean).is_empty());
}

#[test]
fn panic_rule_exempts_test_items() {
    let src = r#"
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let xs = vec![1u64];
        assert_eq!(xs[0], Some(1).unwrap());
    }
}
"#;
    assert!(scan_source("engine/f.rs", src).is_empty());
}

// -- allow escapes -----------------------------------------------------

#[test]
fn allow_escape_suppresses_same_line_and_next_line() {
    let same_line = r#"
pub fn f(m: Option<u64>) -> u64 {
    m.unwrap() // lamps-lint: allow(panic) invariant: set by caller
}
"#;
    assert!(scan_source("engine/f.rs", same_line).is_empty());
    let line_above = r#"
pub fn f(m: Option<u64>) -> u64 {
    // lamps-lint: allow(panic) invariant: set by caller
    m.unwrap()
}
"#;
    assert!(scan_source("engine/f.rs", line_above).is_empty());
}

#[test]
fn allow_escape_requires_known_rule_and_reason() {
    let unknown = r#"
pub fn f(m: Option<u64>) -> u64 {
    m.unwrap() // lamps-lint: allow(yolo) because
}
"#;
    let v = scan_source("engine/f.rs", unknown);
    let hits = rules_hit(&v);
    assert!(hits.contains(&"allow"), "{v:?}");
    assert!(hits.contains(&"panic"), "unknown rule must not suppress");
    let no_reason = r#"
pub fn f(m: Option<u64>) -> u64 {
    m.unwrap() // lamps-lint: allow(panic)
}
"#;
    let v = scan_source("engine/f.rs", no_reason);
    let hits = rules_hit(&v);
    assert!(hits.contains(&"allow"), "{v:?}");
    assert!(hits.contains(&"panic"), "reasonless escape must not \
                                      suppress");
}

#[test]
fn allow_escape_is_rule_specific() {
    let src = r#"
pub fn f(m: Option<u64>) -> u64 {
    m.unwrap() // lamps-lint: allow(wall-clock) wrong rule named
}
"#;
    let v = scan_source("engine/f.rs", src);
    assert!(rules_hit(&v).contains(&"panic"), "{v:?}");
}

// -- wall-clock --------------------------------------------------------

#[test]
fn wall_clock_flags_instant_and_system_time() {
    let src = r#"
pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
pub fn epoch() -> std::time::SystemTime {
    std::time::SystemTime::now()
}
"#;
    let v = scan_source("metrics/t.rs", src);
    let hits = rules_hit(&v);
    assert!(hits.iter().filter(|r| **r == "wall-clock").count() >= 2,
            "{v:?}");
}

#[test]
fn wall_clock_exempts_the_sim_clock_seam() {
    let src = r#"
pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
"#;
    assert!(scan_source("engine/clock.rs", src).is_empty());
}

// -- float-iter --------------------------------------------------------

#[test]
fn float_iter_flags_accumulation_over_hashmap_order() {
    let src = r#"
use std::collections::HashMap;
pub fn total(m: &HashMap<u64, f64>) -> f64 {
    let mut sum = 0.0;
    for v in m.values() {
        sum += v;
    }
    sum
}
"#;
    let v = scan_source("cluster/t.rs", src);
    assert!(rules_hit(&v).contains(&"float-iter"), "{v:?}");
}

#[test]
fn float_iter_flags_iterator_chain_sums() {
    let src = r#"
use std::collections::HashMap;
pub fn total(m: &HashMap<u64, f64>) -> f64 {
    let t = m.values().copied().sum::<f64>();
    t
}
"#;
    let v = scan_source("coordinator/t.rs", src);
    assert!(rules_hit(&v).contains(&"float-iter"), "{v:?}");
}

#[test]
fn float_iter_spares_sorted_collection_and_int_sums() {
    let sorted = r#"
use std::collections::HashMap;
pub fn total(m: &HashMap<u64, f64>) -> f64 {
    let mut vals: Vec<f64> = m.values().copied().collect();
    vals.sort_by(f64::total_cmp);
    let mut sum = 0.0;
    for v in vals {
        sum += v;
    }
    sum
}
"#;
    assert!(scan_source("cluster/t.rs", sorted).is_empty());
    let int_sum = r#"
use std::collections::HashMap;
pub fn count(m: &HashMap<u64, u64>) -> u64 {
    let mut n = 0u64;
    for v in m.values() {
        n += v;
    }
    n
}
"#;
    assert!(scan_source("engine/t.rs", int_sum).is_empty());
}

// -- probe-purity ------------------------------------------------------

#[test]
fn probe_purity_flags_mut_probe_signatures() {
    let src = r#"
pub fn placement_score(engines: &mut [Engine], spec: &RequestSpec)
                       -> f64 {
    engines.len() as f64
}
"#;
    let v = scan_source("coordinator/ranking.rs", src);
    assert!(rules_hit(&v).contains(&"probe-purity"), "{v:?}");
}

#[test]
fn probe_purity_accepts_read_only_probes() {
    let src = r#"
pub fn placement_score(engines: &[Engine], spec: &RequestSpec) -> f64 {
    engines.len() as f64
}
pub fn prefix_credits(engines: &[Engine]) -> Vec<u64> {
    Vec::new()
}
"#;
    assert!(scan_source("coordinator/ranking.rs", src).is_empty());
}

// -- probe-hot-loop ----------------------------------------------------

#[test]
fn probe_hot_loop_flags_hashing_inside_replica_iteration() {
    let src = r#"
pub fn worst(replicas: &[Engine], spec: &RequestSpec) -> usize {
    let mut best = 0;
    for (i, e) in replicas.iter().enumerate() {
        let chain = prefix::content_chain(spec, 16, spec.prompt_tokens);
        if e.score(&chain) > 0 {
            best = i;
        }
    }
    best
}
"#;
    let v = scan_source("cluster/t.rs", src);
    assert!(rules_hit(&v).contains(&"probe-hot-loop"), "{v:?}");
}

#[test]
fn probe_hot_loop_spares_hoisted_and_closure_hashing() {
    // Hoisted above the loop: the one-shot pattern the rule demands.
    let hoisted = r#"
pub fn best(replicas: &[Engine], spec: &RequestSpec) -> usize {
    let chain = prefix::content_chain(spec, 16, spec.prompt_tokens);
    let mut best = 0;
    for (i, e) in replicas.iter().enumerate() {
        if e.score(&chain) > 0 {
            best = i;
        }
    }
    best
}
"#;
    assert!(scan_source("cluster/t.rs", hoisted).is_empty());
    // Lazy one-shot init (ArrivalScratch::chain) is not a loop body.
    let lazy = r#"
impl ArrivalScratch<'_> {
    fn chain(&self) -> &[BlockHash] {
        self.chain.get_or_init(|| {
            prefix::content_chain(self.spec, self.block_size,
                                  self.spec.prompt_tokens)
        })
    }
}
"#;
    assert!(scan_source("cluster/t.rs", lazy).is_empty());
    // Outside cluster/ the rule does not apply (the engine legitimately
    // extends chains while iterating its own admission queue).
    let engine_loop = r#"
pub fn seed(reqs: &[RequestSpec]) {
    for spec in reqs {
        let chain = prefix::content_chain(spec, 16, spec.prompt_tokens);
        drop(chain);
    }
}
"#;
    assert!(scan_source("engine/t.rs", engine_loop).is_empty());
}

#[test]
fn probe_hot_loop_allow_escape_suppresses() {
    let src = r#"
pub fn audit(replicas: &[Engine], spec: &RequestSpec) {
    for e in replicas.iter() {
        // lamps-lint: allow(probe-hot-loop) audit path recomputes on purpose
        let chain = prefix::content_chain(spec, 16, spec.prompt_tokens);
        e.check(&chain);
    }
}
"#;
    assert!(scan_source("cluster/t.rs", src).is_empty());
}

// -- predictor-seam ----------------------------------------------------

#[test]
fn predictor_seam_flags_direct_api_stats_reads() {
    let src = r#"
pub fn eta(api: ApiType) -> Micros {
    api_stats::predicted_duration(api)
}
pub fn budget(api: ApiType) -> u64 {
    api_stats::predicted_response_tokens(api)
}
pub fn spread(api: ApiType) -> f64 {
    api_stats::stats_for(api).duration_secs.1
}
"#;
    let v = scan_source("engine/mod.rs", src);
    let hits = rules_hit(&v);
    assert_eq!(hits.iter().filter(|r| **r == "predictor-seam").count(),
               3, "{v:?}");
}

#[test]
fn predictor_seam_exempts_seam_and_workload_and_spares_seam_calls() {
    let direct = r#"
pub fn eta(api: ApiType) -> Micros {
    api_stats::predicted_duration(api)
}
"#;
    // The seam itself and the trace generators read Table 2 directly.
    assert!(scan_source("predictor/duration.rs", direct).is_empty());
    assert!(scan_source("workload/toolbench.rs", direct).is_empty());
    // Consumers going through the seam re-exports stay clean.
    let through_seam = r#"
pub fn eta(api: ApiType) -> Micros {
    crate::predictor::duration::class_prior_duration(api)
}
"#;
    assert!(scan_source("server/mod.rs", through_seam).is_empty());
}

#[test]
fn predictor_seam_allow_escape_suppresses() {
    let src = r#"
pub fn eta(api: ApiType) -> Micros {
    // lamps-lint: allow(predictor-seam) metrics label only, never scheduled
    api_stats::predicted_duration(api)
}
"#;
    assert!(scan_source("metrics/mod.rs", src).is_empty());
}

// -- gossip-seam -------------------------------------------------------

#[test]
fn gossip_seam_flags_direct_mirror_mutation() {
    let src = r#"
pub fn cheat(index: &mut SharedPrefixIndex, hash: BlockHash) {
    index.mirror_insert(hash, 0);
    index.mirror_remove(hash, 1);
}
"#;
    let v = scan_source("cluster/mod.rs", src);
    let hits = rules_hit(&v);
    assert_eq!(hits.iter().filter(|r| **r == "gossip-seam").count(), 2,
               "{v:?}");
    // The rule applies crate-wide, not just under cluster/.
    let v = scan_source("coordinator/placement.rs", src);
    assert!(rules_hit(&v).contains(&"gossip-seam"), "{v:?}");
}

#[test]
fn gossip_seam_exempts_the_pipeline_and_spares_on_delta() {
    let direct = r#"
pub fn apply(index: &mut SharedPrefixIndex, hash: BlockHash) {
    index.mirror_insert(hash, 0);
}
"#;
    // The index impl and the modeled-network delivery own the mirror.
    assert!(scan_source("cluster/shared_prefix.rs", direct).is_empty());
    assert!(scan_source("cluster/net/mod.rs", direct).is_empty());
    // The delta-sink seam stays legal everywhere.
    let through_seam = r#"
pub fn mirror(index: &mut SharedPrefixIndex, delta: &PrefixDelta) {
    index.on_delta(0, delta);
}
"#;
    assert!(scan_source("cluster/mod.rs", through_seam).is_empty());
}

#[test]
fn gossip_seam_allow_escape_suppresses() {
    let src = r#"
pub fn rebuild(index: &mut SharedPrefixIndex, hash: BlockHash) {
    // lamps-lint: allow(gossip-seam) cold-start rebuild, network not armed yet
    index.mirror_insert(hash, 0);
}
"#;
    assert!(scan_source("cluster/mod.rs", src).is_empty());
}

// -- the on-disk fixture corpus + the crate itself ---------------------

#[test]
fn fixture_corpus_trips_every_rule_and_allows_suppress() {
    let root =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("lint-fixtures");
    let violations = scan_tree(&root).expect("fixture tree readable");
    for rule in RULES {
        assert!(violations.iter().any(|v| v.rule == rule),
                "fixture corpus must seed rule {rule}: {violations:?}");
    }
    assert!(!violations.iter().any(|v| v.file.contains("allowed")),
            "allow-escaped fixture must scan clean: {violations:?}");
}

#[test]
fn crate_sources_are_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let violations = scan_tree(&root).expect("src readable");
    assert!(violations.is_empty(),
            "lamps-lint must exit 0 on the crate:\n{}",
            violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("\n"));
}
