//! `lamps-lint` — the project's own static analysis, distilled from
//! six PRs of review conventions into machine-checked rules (see
//! `bin/lamps-lint.rs` for the CLI and `ROADMAP.md` for the history).
//!
//! A self-contained token-level Rust source scanner — no syn, no
//! external deps (the offline vendor set has none) — that walks
//! `rust/src` and enforces:
//!
//! | rule           | scope                               | violation |
//! |----------------|-------------------------------------|-----------|
//! | `wire-format`  | `server/`                           | JSON assembled via `format!`/`write!`/`push_str` string splicing (the PR 5 injection class) |
//! | `wire-hot-path`| `server/`                           | allocating `util::json` round-trips (`json::parse` / `json::write`) on the serving hot path — frames go through `crate::wire` (the PR 7 zero-copy redesign); `json::obj`/`num`/`s` constructors stay legal |
//! | `panic`        | `server/ cluster/ engine/ kv/ wire/`| `.unwrap()` / `.expect()` / `panic!` / slice-indexing in non-test code |
//! | `wall-clock`   | everywhere but `engine/clock.rs`    | `Instant::now` / `SystemTime` (sim-clock determinism) |
//! | `float-iter`   | `engine/ cluster/ coordinator/`     | f64 accumulation over `HashMap` iteration order (the PR 3 placement-reproducibility class) |
//! | `probe-purity` | everywhere                          | a placement probe (`load_memory_over_time*`, `placement_score*`, `prefix_credits`) taking any `&mut` |
//! | `probe-hot-loop` | `cluster/`                        | prompt hashing (`content_chain` / `extend_content_chain`) inside a `for` loop — per-replica iteration must borrow the arrival's one-shot chain (`ArrivalScratch`), not rehash it per candidate (the PR 8 class) |
//! | `predictor-seam` | everywhere but `predictor/ workload/` | direct Table 2 reads (`api_stats::stats_for` / `predicted_duration` / `predicted_response_tokens`) — consumers go through the `predictor::duration` seam (`DurationModel::revise`, `class_prior_*`) so learned estimators can revise every estimate (the PR 9 class) |
//! | `gossip-seam`  | everywhere but `cluster/net/` and `cluster/shared_prefix.rs` | direct `SharedPrefixIndex` mutation (`mirror_insert` / `mirror_remove`) — the fleet mirror is updated only by journal deltas riding the gossip pipeline (`PrefixDeltaSink::on_delta` stays legal), so no code path can outrun the modeled network (the PR 10 class) |
//!
//! A genuine exception is written down, not waved through:
//!
//! ```text
//! // lamps-lint: allow(panic) invariant: admitted ids are in requests
//! ```
//!
//! The escape names the rule and must carry a non-empty reason; it
//! covers its own line and the next one (so it can sit above the
//! offending line). A malformed escape (unknown rule, missing reason)
//! is itself reported.
//!
//! Test code is exempt: items under a `#[cfg(test)]` / `#[test]`
//! attribute are stripped before the rules run, and files named
//! `tests.rs` (out-of-line test modules) are skipped entirely.

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The nine enforced rule slugs (what `allow(...)` accepts).
pub const RULES: [&str; 9] = [
    "wire-format",
    "wire-hot-path",
    "panic",
    "wall-clock",
    "float-iter",
    "probe-purity",
    "probe-hot-loop",
    "predictor-seam",
    "gossip-seam",
];

/// One finding: file, 1-based line, rule slug, human message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule,
               self.message)
    }
}

// ----------------------------------------------------------------------
// Lexer: a minimal Rust tokenizer. Comments vanish, strings become
// opaque `Str` tokens (body kept for the wire-format rule), lifetimes
// are told apart from char literals, numbers remember whether they are
// floats. Enough structure for every rule; nothing more.
// ----------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Punct(char),
    /// String literal body (quotes stripped, escapes NOT decoded).
    Str(String),
    Num { float: bool },
    CharLit,
    Lifetime,
}

#[derive(Debug, Clone)]
struct Token {
    tok: Tok,
    line: usize,
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn lex(src: &str) -> Vec<Token> {
    let b = src.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                // Block comments nest in Rust.
                let mut depth = 1usize;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/'
                        && i + 1 < b.len()
                        && b[i + 1] == b'*'
                    {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*'
                        && i + 1 < b.len()
                        && b[i + 1] == b'/'
                    {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            b'"' => {
                let start_line = line;
                let (body, ni, nl) = scan_string(b, i + 1);
                out.push(Token { tok: Tok::Str(body), line: start_line });
                line += nl;
                i = ni;
            }
            b'r' | b'b' => {
                if let Some((tok, ni, nl)) = try_prefixed_string(b, i) {
                    out.push(Token { tok, line });
                    line += nl;
                    i = ni;
                } else {
                    let (name, ni) = scan_ident(b, i);
                    out.push(Token { tok: Tok::Ident(name), line });
                    i = ni;
                }
            }
            b'\'' => {
                // Char literal vs lifetime: 'x' / '\n' are chars,
                // 'static / '_ are lifetimes.
                if i + 1 < b.len() && b[i + 1] == b'\\' {
                    // Escaped char literal: skip escape, find quote.
                    let mut j = i + 3;
                    while j < b.len() && b[j] != b'\'' {
                        j += 1;
                    }
                    out.push(Token { tok: Tok::CharLit, line });
                    i = (j + 1).min(b.len());
                } else if i + 2 < b.len() && b[i + 2] == b'\'' {
                    out.push(Token { tok: Tok::CharLit, line });
                    i += 3;
                } else {
                    let mut j = i + 1;
                    while j < b.len() && is_ident_cont(b[j]) {
                        j += 1;
                    }
                    out.push(Token { tok: Tok::Lifetime, line });
                    i = j;
                }
            }
            _ if c.is_ascii_digit() => {
                let mut j = i;
                let mut float = false;
                while j < b.len() {
                    if is_ident_cont(b[j]) {
                        j += 1;
                    } else if b[j] == b'.'
                        && j + 1 < b.len()
                        && b[j + 1].is_ascii_digit()
                    {
                        float = true;
                        j += 1;
                    } else {
                        break;
                    }
                }
                out.push(Token { tok: Tok::Num { float }, line });
                i = j;
            }
            _ if is_ident_start(c) => {
                let (name, ni) = scan_ident(b, i);
                out.push(Token { tok: Tok::Ident(name), line });
                i = ni;
            }
            _ => {
                out.push(Token { tok: Tok::Punct(c as char), line });
                i += 1;
            }
        }
    }
    out
}

fn scan_ident(b: &[u8], i: usize) -> (String, usize) {
    let mut j = i;
    while j < b.len() && is_ident_cont(b[j]) {
        j += 1;
    }
    (String::from_utf8_lossy(&b[i..j]).into_owned(), j)
}

/// Scan a normal (escape-aware) string body starting just past the
/// opening quote. Returns (body, index past closing quote, newlines).
fn scan_string(b: &[u8], mut i: usize) -> (String, usize, usize) {
    let start = i;
    let mut newlines = 0usize;
    while i < b.len() {
        match b[i] {
            b'\\' => i = (i + 2).min(b.len()),
            b'"' => {
                let body =
                    String::from_utf8_lossy(&b[start..i]).into_owned();
                return (body, i + 1, newlines);
            }
            b'\n' => {
                newlines += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    (String::from_utf8_lossy(&b[start..]).into_owned(), i, newlines)
}

/// Raw/byte string starting at `r` / `b` / `br` / `rb`. `None` means
/// "just an identifier" and the caller lexes it as one.
fn try_prefixed_string(b: &[u8], i: usize) -> Option<(Tok, usize, usize)> {
    let mut j = i;
    let mut raw = false;
    while j < b.len() && (b[j] == b'r' || b[j] == b'b') && j - i < 2 {
        raw |= b[j] == b'r';
        j += 1;
    }
    if j >= b.len() {
        return None;
    }
    if raw {
        // r"..."  r#"..."#  br##"..."## — no escapes inside.
        let mut hashes = 0usize;
        while j < b.len() && b[j] == b'#' {
            hashes += 1;
            j += 1;
        }
        if j >= b.len() || b[j] != b'"' {
            return None;
        }
        j += 1;
        let start = j;
        let mut newlines = 0usize;
        while j < b.len() {
            if b[j] == b'\n' {
                newlines += 1;
            }
            if b[j] == b'"' {
                let mut k = j + 1;
                let mut seen = 0usize;
                while k < b.len() && b[k] == b'#' && seen < hashes {
                    seen += 1;
                    k += 1;
                }
                if seen == hashes {
                    let body = String::from_utf8_lossy(&b[start..j])
                        .into_owned();
                    return Some((Tok::Str(body), k, newlines));
                }
            }
            j += 1;
        }
        let body = String::from_utf8_lossy(&b[start..]).into_owned();
        Some((Tok::Str(body), j, newlines))
    } else {
        // b"..." — escape-aware like a normal string.
        if b[j] != b'"' {
            return None;
        }
        let (body, ni, nl) = scan_string(b, j + 1);
        Some((Tok::Str(body), ni, nl))
    }
}

// ----------------------------------------------------------------------
// Test-code stripping: drop any item annotated `#[cfg(test)]` /
// `#[test]` (attribute plus the whole item body) before rules run.
// ----------------------------------------------------------------------

fn strip_test_items(tokens: Vec<Token>) -> Vec<Token> {
    let mut out = Vec::with_capacity(tokens.len());
    let mut i = 0usize;
    let mut skip_pending = false;
    while i < tokens.len() {
        let is_attr = matches!(tokens[i].tok, Tok::Punct('#'))
            && matches!(tokens.get(i + 1).map(|t| &t.tok),
                        Some(Tok::Punct('[')));
        if is_attr {
            // Collect the attribute to its matching `]`.
            let mut j = i + 2;
            let mut depth = 1usize;
            let mut has_test = false;
            while j < tokens.len() && depth > 0 {
                match &tokens[j].tok {
                    Tok::Punct('[') => depth += 1,
                    Tok::Punct(']') => depth -= 1,
                    Tok::Ident(s) if s == "test" => has_test = true,
                    _ => {}
                }
                j += 1;
            }
            if has_test || skip_pending {
                skip_pending = true;
            } else {
                out.extend_from_slice(&tokens[i..j]);
            }
            i = j;
            continue;
        }
        if skip_pending {
            // Drop the attributed item: to `;` at bracket depth 0, or
            // through the body of the first `{` opened at depth 0.
            let mut depth = 0isize;
            while i < tokens.len() {
                match &tokens[i].tok {
                    Tok::Punct('(') | Tok::Punct('[') => depth += 1,
                    Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
                    Tok::Punct(';') if depth == 0 => {
                        i += 1;
                        break;
                    }
                    Tok::Punct('{') if depth == 0 => {
                        let mut braces = 1usize;
                        i += 1;
                        while i < tokens.len() && braces > 0 {
                            match &tokens[i].tok {
                                Tok::Punct('{') => braces += 1,
                                Tok::Punct('}') => braces -= 1,
                                _ => {}
                            }
                            i += 1;
                        }
                        break;
                    }
                    _ => {}
                }
                i += 1;
            }
            skip_pending = false;
            continue;
        }
        out.push(tokens[i].clone());
        i += 1;
    }
    out
}

// ----------------------------------------------------------------------
// Allow escapes
// ----------------------------------------------------------------------

/// Parse `// lamps-lint: allow(<rule>) <reason>` escapes. An escape
/// covers its own line and the next. Malformed escapes are reported.
fn parse_allows(src: &str)
                -> (HashMap<usize, Vec<&'static str>>, Vec<Violation>) {
    let mut allows: HashMap<usize, Vec<&'static str>> = HashMap::new();
    let mut bad = Vec::new();
    for (idx, text) in src.lines().enumerate() {
        let line = idx + 1;
        let Some(comment_at) = text.find("//") else { continue };
        let comment = &text[comment_at..];
        let Some(at) = comment.find("lamps-lint:") else { continue };
        let rest = comment[at + "lamps-lint:".len()..].trim_start();
        let Some(args) = rest.strip_prefix("allow(") else {
            bad.push(Violation {
                file: String::new(),
                line,
                rule: "allow",
                message: "malformed lamps-lint escape (expected \
                          `lamps-lint: allow(<rule>) <reason>`)"
                    .to_string(),
            });
            continue;
        };
        let Some(close) = args.find(')') else {
            bad.push(Violation {
                file: String::new(),
                line,
                rule: "allow",
                message: "unclosed lamps-lint allow(...)".to_string(),
            });
            continue;
        };
        let slug = args[..close].trim();
        let reason = args[close + 1..].trim();
        let Some(&known) = RULES.iter().find(|r| **r == slug) else {
            bad.push(Violation {
                file: String::new(),
                line,
                rule: "allow",
                message: format!("unknown lint rule '{slug}' in allow \
                                  escape"),
            });
            continue;
        };
        if reason.is_empty() {
            bad.push(Violation {
                file: String::new(),
                line,
                rule: "allow",
                message: format!("allow({known}) escape carries no \
                                  reason"),
            });
            continue;
        }
        allows.entry(line).or_default().push(known);
        allows.entry(line + 1).or_default().push(known);
    }
    (allows, bad)
}

// ----------------------------------------------------------------------
// Rules
// ----------------------------------------------------------------------

/// Idents that may directly precede `[` without it being an index
/// expression (`&mut [Engine]`, `let [a, b] = ..`, `for x in [..]`).
const NON_INDEX_KEYWORDS: [&str; 24] = [
    "mut", "dyn", "ref", "in", "as", "return", "break", "continue",
    "else", "match", "move", "const", "static", "crate", "super",
    "impl", "where", "let", "fn", "if", "while", "loop", "for",
    "unsafe",
];

fn id_at<'a>(t: &'a [Token], i: usize) -> Option<&'a str> {
    match t.get(i).map(|tk| &tk.tok) {
        Some(Tok::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

fn punct_at(t: &[Token], i: usize, c: char) -> bool {
    matches!(t.get(i).map(|tk| &tk.tok), Some(Tok::Punct(p)) if *p == c)
}

fn in_dir(rel: &str, dir: &str) -> bool {
    rel.starts_with(&format!("{dir}/"))
}

struct Ctx<'a> {
    file: &'a str,
    allows: HashMap<usize, Vec<&'static str>>,
    out: Vec<Violation>,
}

impl Ctx<'_> {
    fn push(&mut self, line: usize, rule: &'static str, message: String) {
        let allowed = self
            .allows
            .get(&line)
            .is_some_and(|rules| rules.contains(&rule));
        if !allowed {
            self.out.push(Violation {
                file: self.file.to_string(),
                line,
                rule,
                message,
            });
        }
    }
}

/// Scan one file's source under its `src/`-relative path (forward
/// slashes). The path decides which rules apply.
pub fn scan_source(rel_path: &str, src: &str) -> Vec<Violation> {
    let rel = rel_path.replace('\\', "/");
    let (allows, mut bad_allows) = parse_allows(src);
    for v in &mut bad_allows {
        v.file = rel.clone();
    }
    let tokens = strip_test_items(lex(src));
    let mut ctx = Ctx { file: &rel, allows, out: Vec::new() };

    let panic_scope = ["server", "cluster", "engine", "kv", "wire"]
        .iter()
        .any(|d| in_dir(&rel, d));
    let float_scope = ["engine", "cluster", "coordinator"]
        .iter()
        .any(|d| in_dir(&rel, d));
    let clock_scope = rel != "engine/clock.rs";
    let wire_scope = in_dir(&rel, "server");
    let hot_loop_scope = in_dir(&rel, "cluster");
    let seam_scope = !["predictor", "workload"]
        .iter()
        .any(|d| in_dir(&rel, d));
    let gossip_scope =
        !in_dir(&rel, "cluster/net") && rel != "cluster/shared_prefix.rs";

    if panic_scope {
        rule_panic(&tokens, &mut ctx);
    }
    if clock_scope {
        rule_wall_clock(&tokens, &mut ctx);
    }
    if wire_scope {
        rule_wire_format(&tokens, &mut ctx);
        rule_wire_hot_path(&tokens, &mut ctx);
    }
    if float_scope {
        rule_float_iter(&tokens, &mut ctx);
    }
    if hot_loop_scope {
        rule_probe_hot_loop(&tokens, &mut ctx);
    }
    if seam_scope {
        rule_predictor_seam(&tokens, &mut ctx);
    }
    if gossip_scope {
        rule_gossip_seam(&tokens, &mut ctx);
    }
    rule_probe_purity(&tokens, &mut ctx);

    let mut out = ctx.out;
    out.extend(bad_allows);
    out.sort_by_key(|v| v.line);
    out
}

/// Rule `panic`: `.unwrap()` / `.expect()` / `panic!`-family macros /
/// slice-indexing in non-test scheduler-critical code.
fn rule_panic(t: &[Token], ctx: &mut Ctx<'_>) {
    for i in 0..t.len() {
        let line = t[i].line;
        if let Some(name) = id_at(t, i) {
            match name {
                "unwrap" | "expect"
                    if punct_at(t, i.wrapping_sub(1), '.')
                        && punct_at(t, i + 1, '(') =>
                {
                    ctx.push(line, "panic", format!(
                        ".{name}() in scheduler-critical code — \
                         handle the miss or annotate the invariant"));
                }
                "panic" | "unreachable" | "todo" | "unimplemented"
                    if punct_at(t, i + 1, '!') =>
                {
                    ctx.push(line, "panic", format!(
                        "{name}! in scheduler-critical code — return \
                         an error or annotate the invariant"));
                }
                _ => {}
            }
        }
        if punct_at(t, i, '[') && i > 0 {
            let indexes = match &t[i - 1].tok {
                Tok::Punct(')') | Tok::Punct(']') => true,
                Tok::Ident(s) => {
                    !NON_INDEX_KEYWORDS.contains(&s.as_str())
                }
                _ => false,
            };
            if indexes {
                ctx.push(line, "panic",
                         "slice/map indexing can panic — use .get() \
                          or annotate the bounds invariant"
                             .to_string());
            }
        }
    }
}

/// Rule `wall-clock`: `Instant::now` / `SystemTime` anywhere outside
/// `engine/clock.rs` (simulation determinism — real time may only
/// enter through the sim clock seam or an annotated TCP-layer site).
fn rule_wall_clock(t: &[Token], ctx: &mut Ctx<'_>) {
    for i in 0..t.len() {
        let Some(name) = id_at(t, i) else { continue };
        if name == "Instant"
            && punct_at(t, i + 1, ':')
            && punct_at(t, i + 2, ':')
            && id_at(t, i + 3) == Some("now")
        {
            ctx.push(t[i].line, "wall-clock",
                     "Instant::now outside engine/clock.rs breaks \
                      virtual-clock determinism"
                         .to_string());
        }
        if name == "SystemTime" {
            ctx.push(t[i].line, "wall-clock",
                     "SystemTime outside engine/clock.rs breaks \
                      virtual-clock determinism"
                         .to_string());
        }
    }
}

/// Rule `wire-format`: string-formatted JSON in `server/` (a `{"`
/// skeleton inside a `format!`/`write!`/`writeln!`/`push_str`
/// argument). Frames must go through `util::json::obj`, which escapes.
fn rule_wire_format(t: &[Token], ctx: &mut Ctx<'_>) {
    for i in 0..t.len() {
        let Some(name) = id_at(t, i) else { continue };
        let is_macro = matches!(name, "format" | "write" | "writeln")
            && punct_at(t, i + 1, '!');
        let is_push = name == "push_str"
            && punct_at(t, i.wrapping_sub(1), '.');
        if !is_macro && !is_push {
            continue;
        }
        // Examine string literals inside the call's parentheses.
        let mut j = i + 1;
        while j < t.len() && !punct_at(t, j, '(') {
            j += 1;
        }
        let mut depth = 0isize;
        while j < t.len() {
            match &t[j].tok {
                Tok::Punct('(') => depth += 1,
                Tok::Punct(')') => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                Tok::Str(body)
                    if body.contains("{\"")
                        || body.contains("{\\\"") =>
                {
                    ctx.push(t[i].line, "wire-format",
                             "JSON spliced via string formatting in \
                              server/ — build the frame with \
                              util::json::obj (PR 5 injection class)"
                                 .to_string());
                    break;
                }
                _ => {}
            }
            j += 1;
        }
    }
}

/// Rule `wire-hot-path`: allocating `util::json` round-trips in
/// `server/` non-test code. Every per-frame path speaks `crate::wire`
/// (borrowed-slice `Frame::parse`, reusable `Encoder`) since the PR 7
/// redesign; a `json::parse` / `json::write` call there reintroduces
/// the Value-tree allocation storm the wire layer removed. The typed
/// constructors (`json::obj` / `json::num` / `json::s`) stay legal —
/// they feed cold paths like report serialization, not the pump.
fn rule_wire_hot_path(t: &[Token], ctx: &mut Ctx<'_>) {
    for i in 0..t.len() {
        if id_at(t, i) != Some("json")
            || !punct_at(t, i + 1, ':')
            || !punct_at(t, i + 2, ':')
        {
            continue;
        }
        let Some(name) = id_at(t, i + 3) else { continue };
        if !matches!(name, "parse" | "write") {
            continue;
        }
        if !punct_at(t, i + 4, '(') {
            continue;
        }
        ctx.push(t[i].line, "wire-hot-path", format!(
            "json::{name} on the server hot path — frames go through \
             crate::wire (Frame::parse / Encoder), not the allocating \
             Value tree (PR 7 zero-copy class)"));
    }
}

/// Rule `predictor-seam`: direct Table 2 reads outside `predictor/`
/// and `workload/`. A raw `api_stats::stats_for` /
/// `predicted_duration` / `predicted_response_tokens` call bypasses
/// the `predictor::duration` seam, so learned estimators never get to
/// revise that estimate and the `--api-pred` knob silently stops
/// covering the call site (the PR 9 class). Consumers read through
/// `DurationModel::revise` or the `class_prior_*` re-exports instead;
/// workload generators sample the same Table 2 distributions and are
/// exempt by scope.
fn rule_predictor_seam(t: &[Token], ctx: &mut Ctx<'_>) {
    for i in 0..t.len() {
        let Some(name) = id_at(t, i) else { continue };
        if !matches!(name, "stats_for" | "predicted_duration"
                           | "predicted_response_tokens")
        {
            continue;
        }
        if !punct_at(t, i + 1, '(') {
            continue;
        }
        ctx.push(t[i].line, "predictor-seam", format!(
            "direct api_stats::{name} call bypasses the duration \
             seam — read through predictor::duration \
             (DurationModel::revise / class_prior_*) so learned \
             estimators stay in the loop (PR 9 class)"));
    }
}

/// Rule `gossip-seam`: direct `SharedPrefixIndex` mutation outside
/// `cluster/net/` and `cluster/shared_prefix.rs`. A raw
/// `mirror_insert` / `mirror_remove` call lets fleet state outrun the
/// modeled network — the mirror must only change via journal deltas
/// riding the gossip pipeline (the `PrefixDeltaSink::on_delta` seam,
/// which stays legal everywhere), or `--net-model` byte-identity and
/// the bounded-staleness audit both silently rot (the PR 10 class).
fn rule_gossip_seam(t: &[Token], ctx: &mut Ctx<'_>) {
    for i in 0..t.len() {
        let Some(name) = id_at(t, i) else { continue };
        if !matches!(name, "mirror_insert" | "mirror_remove") {
            continue;
        }
        if !punct_at(t, i + 1, '(') {
            continue;
        }
        ctx.push(t[i].line, "gossip-seam", format!(
            "direct SharedPrefixIndex::{name} call bypasses the gossip \
             pipeline — mutate the mirror only through journal deltas \
             (PrefixDeltaSink::on_delta / cluster::net delivery) so \
             fleet state cannot outrun the modeled network (PR 10 \
             class)"));
    }
}

/// Rule `float-iter`: f64 accumulation over `HashMap` iteration order.
/// HashMap order is per-process random and f64 addition is not
/// associative, so such sums differ run to run (the PR 3 placement
/// bug). Collect-and-sort (or iterate a BTree/sorted Vec) instead.
fn rule_float_iter(t: &[Token], ctx: &mut Ctx<'_>) {
    // Pass 1: names declared (or bound) as HashMap.
    let mut hashmaps: HashSet<String> = HashSet::new();
    for i in 0..t.len() {
        if id_at(t, i) != Some("HashMap") {
            continue;
        }
        let mut j = i;
        while j > 0 {
            j -= 1;
            match &t[j].tok {
                Tok::Punct(':') | Tok::Punct('=') | Tok::Punct('<')
                | Tok::Punct('&') => continue,
                Tok::Ident(s) if s == "mut" => continue,
                Tok::Ident(s) => {
                    hashmaps.insert(s.clone());
                    break;
                }
                _ => break,
            }
        }
    }
    // Pass 2: names declared/initialized as floats.
    let mut floats: HashSet<String> = HashSet::new();
    for i in 0..t.len() {
        if id_at(t, i) == Some("f64") && punct_at(t, i.wrapping_sub(1), ':')
        {
            if let Some(name) = id_at(t, i.wrapping_sub(2)) {
                floats.insert(name.to_string());
            }
        }
        if matches!(t[i].tok, Tok::Num { float: true })
            && punct_at(t, i.wrapping_sub(1), '=')
            && !punct_at(t, i.wrapping_sub(2), '+')
        {
            if let Some(name) = id_at(t, i.wrapping_sub(2)) {
                floats.insert(name.to_string());
            }
        }
    }
    // Pass 3: for-loops whose header mentions a HashMap and whose body
    // accumulates into a float.
    for i in 0..t.len() {
        if id_at(t, i) != Some("for") {
            continue;
        }
        // Header: tokens to the loop's `{` at bracket depth 0.
        let mut j = i + 1;
        let mut depth = 0isize;
        let mut over_map = false;
        while j < t.len() {
            match &t[j].tok {
                Tok::Punct('(') | Tok::Punct('[') => depth += 1,
                Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
                Tok::Punct('{') if depth == 0 => break,
                Tok::Ident(s) if hashmaps.contains(s) => over_map = true,
                _ => {}
            }
            j += 1;
        }
        if !over_map || j >= t.len() {
            continue;
        }
        // Body: to the matching `}`.
        let body_start = j + 1;
        let mut braces = 1usize;
        let mut k = body_start;
        while k < t.len() && braces > 0 {
            match &t[k].tok {
                Tok::Punct('{') => braces += 1,
                Tok::Punct('}') => braces -= 1,
                _ => {}
            }
            k += 1;
        }
        let body = &t[body_start..k];
        let mut accumulates = false;
        for m in 0..body.len() {
            if punct_at(body, m, '+') && punct_at(body, m + 1, '=') {
                let lhs_float = id_at(body, m.wrapping_sub(1))
                    .is_some_and(|n| floats.contains(n));
                let rhs_float = matches!(
                    body.get(m + 2).map(|tk| &tk.tok),
                    Some(Tok::Num { float: true }));
                let casts = body.iter().zip(body.iter().skip(1)).any(
                    |(a, b)| matches!(&a.tok, Tok::Ident(s) if s == "as")
                        && matches!(&b.tok,
                                    Tok::Ident(s) if s == "f64"));
                if lhs_float || rhs_float || casts {
                    accumulates = true;
                    break;
                }
            }
        }
        if accumulates {
            ctx.push(t[i].line, "float-iter",
                     "f64 accumulation over HashMap iteration order is \
                      nondeterministic — collect and sort first (PR 3 \
                      placement class)"
                         .to_string());
        }
    }
    // Pass 4: iterator-chain sums (`map.values().map(..).sum::<f64>()`).
    for i in 0..t.len() {
        let Some(name) = id_at(t, i) else { continue };
        if !hashmaps.contains(name) {
            continue;
        }
        let mut saw_iter = false;
        let mut saw_sum = false;
        let mut saw_f64 = false;
        let mut j = i + 1;
        while j < t.len() && !punct_at(t, j, ';') {
            match id_at(t, j) {
                Some("values") | Some("keys") | Some("iter")
                | Some("values_mut") => saw_iter = true,
                Some("sum") => saw_sum = true,
                Some("f64") => saw_f64 = true,
                _ => {}
            }
            j += 1;
        }
        if saw_iter && saw_sum && saw_f64 {
            ctx.push(t[i].line, "float-iter",
                     "f64 sum over HashMap iteration order is \
                      nondeterministic — collect and sort first (PR 3 \
                      placement class)"
                         .to_string());
        }
    }
}

/// Rule `probe-hot-loop`: prompt hashing inside per-replica iteration.
/// A `content_chain` / `extend_content_chain` call in a `for`-loop body
/// in `cluster/` redoes O(prompt) hashing once per candidate replica —
/// the arrival's chain must be computed once (`ArrivalScratch`) and
/// borrowed by every probe (the PR 8 one-shot-hashing class).
fn rule_probe_hot_loop(t: &[Token], ctx: &mut Ctx<'_>) {
    for i in 0..t.len() {
        if id_at(t, i) != Some("for") {
            continue;
        }
        // `impl Trait for Type { .. }` and `for<'a>` bounds also spell
        // `for`; a loop's `for` starts a statement, so the preceding
        // token is never an identifier, `>`, `&`, `:`, or `+`.
        if i > 0
            && matches!(&t[i - 1].tok,
                        Tok::Ident(_) | Tok::Punct('>') | Tok::Punct('&')
                        | Tok::Punct(':') | Tok::Punct('+'))
        {
            continue;
        }
        // Header: tokens to the loop's `{` at bracket depth 0.
        let mut j = i + 1;
        let mut depth = 0isize;
        while j < t.len() {
            match &t[j].tok {
                Tok::Punct('(') | Tok::Punct('[') => depth += 1,
                Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
                Tok::Punct('{') if depth == 0 => break,
                _ => {}
            }
            j += 1;
        }
        if j >= t.len() {
            continue;
        }
        // Body: to the matching `}`.
        let body_start = j + 1;
        let mut braces = 1usize;
        let mut k = body_start;
        while k < t.len() && braces > 0 {
            match &t[k].tok {
                Tok::Punct('{') => braces += 1,
                Tok::Punct('}') => braces -= 1,
                _ => {}
            }
            k += 1;
        }
        for m in body_start..k {
            let hasher = matches!(
                id_at(t, m),
                Some("content_chain") | Some("extend_content_chain"));
            if hasher && punct_at(t, m + 1, '(') {
                ctx.push(t[m].line, "probe-hot-loop",
                         "prompt hashing inside a per-replica loop redoes \
                          O(prompt) work per candidate — hash once into an \
                          ArrivalScratch and borrow the chain (PR 8 \
                          one-shot-hashing class)"
                             .to_string());
            }
        }
    }
}

/// Rule `probe-purity`: placement probes must be read-only. Any `&mut`
/// in the signature of `load_memory_over_time*` / `placement_score*` /
/// `prefix_credits` means a probe can perturb the state it scores —
/// the PR 3 side-effect class.
fn rule_probe_purity(t: &[Token], ctx: &mut Ctx<'_>) {
    for i in 0..t.len() {
        if id_at(t, i) != Some("fn") {
            continue;
        }
        let Some(name) = id_at(t, i + 1) else { continue };
        let is_probe = name.starts_with("load_memory_over_time")
            || name.starts_with("placement_score")
            || name == "prefix_credits";
        if !is_probe {
            continue;
        }
        // Parameter list: first `(` after the name, to its match.
        let mut j = i + 2;
        while j < t.len() && !punct_at(t, j, '(') {
            j += 1;
        }
        let mut depth = 0isize;
        while j < t.len() {
            if punct_at(t, j, '(') {
                depth += 1;
            } else if punct_at(t, j, ')') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if punct_at(t, j, '&')
                && id_at(t, j + 1) == Some("mut")
            {
                ctx.push(t[i].line, "probe-purity", format!(
                    "placement probe {name} takes &mut — probes must \
                     be read-only (&self / &[Engine])"));
                break;
            }
            j += 1;
        }
    }
}

// ----------------------------------------------------------------------
// Tree walk
// ----------------------------------------------------------------------

/// Scan every `.rs` file under `root` (skipping out-of-line test
/// modules named `tests.rs`), in sorted order for stable output.
pub fn scan_tree(root: &Path) -> io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for path in files {
        if path.file_name().is_some_and(|n| n == "tests.rs") {
            continue;
        }
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(&path)?;
        out.extend(scan_source(&rel, &src));
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests;
