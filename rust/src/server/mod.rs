//! Serving frontend: a dedicated engine thread in wall-clock mode, fed
//! through a channel, exposing a **session API** — every submission is
//! an event-streaming session ([`ServerHandle::open_session`] →
//! [`SessionHandle`]) delivering typed [`RequestEvent`]s from `Queued`
//! through exactly one terminal `Finished`/`Dropped`.
//! [`ServerHandle::submit_blocking`] is a thin drain-to-terminal
//! wrapper over a session, so one-shot callers keep working unchanged.
//!
//! With `--api-source external` the engine does not simulate API
//! durations: `ApiCallStarted` is pushed to the client, the request is
//! parked under the strategy chosen from the *predicted* duration, and
//! the call completes only when the client posts the tool result back
//! ([`SessionHandle::complete_api_call`], or a `tool_result` wire
//! frame).
//!
//! # Wire protocol v2 (JSON lines over TCP, [`serve_tcp`])
//!
//! Both directions of the protocol are typed in [`crate::wire`]:
//! inbound lines decode through the zero-copy [`crate::wire::Frame`]
//! lexer (strings borrow the read buffer unless they contain escapes)
//! and outbound frames are [`crate::wire::EventFrame`] values encoded
//! into a reusable per-connection buffer. Client → server, one JSON
//! object per line:
//!
//! - `{"type": "request", "prompt": "...", "output_tokens": N,
//!    "api_calls": [{"decode_before": N, "api_type": "qa",
//!    "api_ms": N, "response_tokens": N}, ...]}`
//!   ([`crate::wire::Frame::Request`]) opens a session. `api_calls`
//!   may name any Table 2 class
//!   (`math|qa|ve|chatbot|image|tts|tool`); `api_ms` is the simulated
//!   duration — under an external source it is only a prediction hint,
//!   and omitted it defaults to the class's historical mean, read
//!   through the duration seam (`predictor::duration`).
//!   `response_tokens` defaults to 4.
//! - `{"type": "tool_result", "id": N, "index": N,
//!    "response_tokens": N}` ([`crate::wire::Frame::ToolResult`])
//!   resolves session `N`'s externally-held API call `index`; the
//!   response length the tool actually produced replaces the spec's.
//! - `{"type": "cancel", "id": N}` ([`crate::wire::Frame::Cancel`]) is
//!   **reserved**: the frame type parses and is acknowledged with a
//!   session-scoped `error` frame, but cancellation is not implemented
//!   yet — the session keeps streaming. Reserving the type now means
//!   old servers already answer it with a well-formed frame instead of
//!   `unknown frame type`.
//! - A line with **no** `type` field is a legacy v1 request
//!   (`{"prompt", "output_tokens", "pre_api_tokens", "api_ms"}`,
//!   [`crate::wire::Frame::V1Request`]): the server replies with a
//!   single [`Completion`] object and no event frames — existing
//!   clients keep working.
//!
//! Server → client, one JSON frame per line, each carrying `type` and
//! the session `id`: `queued`, `placed` (`replica`), `rescued`
//! (`from`, `to`), `first_token`, `tokens` (`chunk`),
//! `api_call_started` (`index`, `strategy`, `predicted_us`,
//! `external`), `api_call_completed` (`index`, `actual_us`),
//! `finished` (the completion fields), `dropped` (`reason`), and
//! `error` (`error`). See `examples/protocol_v2.ndjson` for a worked
//! transcript.
//!
//! (The offline vendor set has no tokio; the frontend is std-thread
//! based. Each TCP connection gets its own reader thread plus one
//! writer pump batching all of its sessions' event frames into one
//! buffered write per drain — adequate for the demo-scale deployments
//! this CPU image can serve.)
//!
//! # Correctness tooling
//!
//! Every outbound frame is encoded through the typed
//! [`crate::wire::Encoder`] — splicing client text into a JSON
//! skeleton by hand is banned by `lamps-lint`'s `wire-format` rule
//! (the PR 5 injection class), and calling the allocating
//! [`crate::util::json`] reader/writer from this module's non-test
//! code is banned by its `wire-hot-path` rule (the typed wire layer is
//! byte-for-byte compatible, so there is never a reason to fall back).
//! The `panic` rule keeps this layer's hot paths on logged-teardown
//! error handling rather than unwraps. In debug builds each replica
//! engine additionally runs the [`crate::audit`] invariant auditor
//! after every step, so the randomized session/fuzz tests
//! (`tests/session_events.rs`, `tests/wire_fuzz.rs`) exercise the
//! full event-causality machine end to end.

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::cluster::PrefixDeltaSink;
use crate::config::{ApiSourceKind, SystemConfig};
use crate::core::request::{HandlingStrategy, RequestSpec};
use crate::core::types::{Micros, RequestId, Tokens};
use crate::engine::backend::Backend;
use crate::engine::clock::Clock;
use crate::engine::{Engine, EngineEvent};
use crate::predictor::Predictor;
use crate::util::json::{self, Value};
use crate::wire::{self, EventFrame, FrameReader, WireLine};

/// Idle poll period of the engine thread — also the cap on how long one
/// replica's in-step wall-clock wait may stall the shared loop.
const POLL_TICK: Micros = Micros(200);

/// Backstop for clients that vanish mid-tool-call: an externally-held
/// API call parked longer than this is aborted
/// ([`Engine::abort_external_call`]) so a dead client cannot pin a
/// replica's KV blocks — or its session and pump thread — forever. A
/// parked external call emits no events, so a dropped connection is
/// undetectable by failed sends until this fires.
const EXTERNAL_CALL_TIMEOUT: Micros = Micros(600_000_000); // 10 min

/// Cadence of the timeout sweep (it scans every open session).
const TIMEOUT_SWEEP_PERIOD: Duration = Duration::from_secs(1);

/// Soft cap on how many encoded event bytes one pump drain batches
/// before flushing to the socket. The pump blocks for the first event,
/// then opportunistically folds every already-queued event into the
/// same buffer up to this bound — one buffered write per drain instead
/// of one write + flush per frame — so a session streaming per-token
/// `tokens` frames costs syscalls proportional to drains, not events.
const PUMP_DRAIN_BYTES: usize = 32 * 1024;

/// What the client receives when its request finishes.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub latency_us: u64,
    pub ttft_us: Option<u64>,
    pub tokens_decoded: u64,
    /// Real model outputs when the engine runs on the PJRT backend.
    pub generated: Option<Vec<i32>>,
    /// `Some(reason)` when the request was dropped unserved (it could
    /// never fit, or its context outgrew the budget mid-run) — what
    /// distinguishes a drop from a legitimately zero-token serve. The
    /// key is omitted from the JSON for served completions.
    pub dropped: Option<String>,
}

impl Completion {
    pub fn to_value(&self) -> Value {
        let mut pairs = vec![
            ("id", json::num(self.id as f64)),
            ("latency_us", json::num(self.latency_us as f64)),
            ("tokens_decoded", json::num(self.tokens_decoded as f64)),
        ];
        pairs.push(("ttft_us", match self.ttft_us {
            Some(t) => json::num(t as f64),
            None => Value::Null,
        }));
        pairs.push(("generated", match &self.generated {
            Some(toks) => Value::Arr(
                toks.iter().map(|t| json::num(*t as f64)).collect()),
            None => Value::Null,
        }));
        if let Some(reason) = &self.dropped {
            pairs.push(("dropped", json::s(reason)));
        }
        json::obj(pairs)
    }

    /// This completion as a borrowed wire frame payload (shared by the
    /// v1 one-shot reply and the v2 `finished` event frame).
    pub fn wire_frame(&self) -> wire::CompletionFrame<'_> {
        wire::CompletionFrame {
            id: self.id,
            latency_us: self.latency_us,
            ttft_us: self.ttft_us,
            tokens_decoded: self.tokens_decoded,
            generated: self.generated.as_deref(),
            dropped: self.dropped.as_deref(),
        }
    }

    pub fn to_json(&self) -> String {
        wire::Encoder::frame_to_string(
            &EventFrame::Completion(self.wire_frame()))
    }
}

/// One event of a request's lifecycle, delivered in causal order over
/// a session's stream: `Queued` ≤ `Placed` ≤ (`Rescued`) ≤
/// `FirstToken` ≤ `Tokens`* ≤ `Finished`, with
/// `ApiCallStarted`/`ApiCallCompleted` pairs in between, and exactly
/// one terminal event (`Finished` xor `Dropped`) closing the stream.
#[derive(Debug, Clone)]
pub enum RequestEvent {
    /// Accepted by the server; an id has been assigned.
    Queued,
    /// Dispatched to `replica` by the placement policy.
    Placed { replica: usize },
    /// Moved by the admission re-queue — subsequent events come from
    /// the new owner.
    Rescued { from: usize, to: usize },
    /// First token decoded (the TTFT mark).
    FirstToken,
    /// `chunk` further tokens decoded.
    Tokens { chunk: u64 },
    /// The request hit API call `index` and was parked under
    /// `strategy`, chosen from `predicted_us`. When `external`, the
    /// client owns the call: the engine will hold the request until a
    /// `tool_result` for this index arrives.
    ApiCallStarted {
        index: usize,
        strategy: HandlingStrategy,
        predicted_us: u64,
        external: bool,
    },
    /// API call `index` returned after `actual_us`.
    ApiCallCompleted { index: usize, actual_us: u64 },
    /// Terminal: served to completion.
    Finished(Completion),
    /// Terminal: dropped unserved.
    Dropped { reason: String },
    /// Non-terminal protocol error scoped to this session — e.g. a
    /// `tool_result` the engine rejected (wrong index, duplicate
    /// fire). The call it misdirected is still parked; a corrected
    /// `tool_result` can follow.
    Error { message: String },
}

impl RequestEvent {
    pub fn is_terminal(&self) -> bool {
        matches!(self,
                 RequestEvent::Finished(_) | RequestEvent::Dropped { .. })
    }

    /// This event as a borrowed typed wire frame carrying session
    /// `id` — what the connection pump encodes. Key order and number
    /// formatting are pinned to the old `util::json` writer by
    /// [`crate::wire::Encoder`]'s tests.
    pub fn wire_frame(&self, id: u64) -> EventFrame<'_> {
        match self {
            RequestEvent::Queued => EventFrame::Queued { id },
            RequestEvent::Placed { replica } => EventFrame::Placed {
                id,
                replica: *replica as u64,
            },
            RequestEvent::Rescued { from, to } => EventFrame::Rescued {
                id,
                from: *from as u64,
                to: *to as u64,
            },
            RequestEvent::FirstToken => EventFrame::FirstToken { id },
            RequestEvent::Tokens { chunk } => EventFrame::Tokens {
                id,
                chunk: *chunk,
            },
            RequestEvent::ApiCallStarted {
                index,
                strategy,
                predicted_us,
                external,
            } => EventFrame::ApiCallStarted {
                id,
                index: *index as u64,
                strategy: strategy.label(),
                predicted_us: *predicted_us,
                external: *external,
            },
            RequestEvent::ApiCallCompleted { index, actual_us } => {
                EventFrame::ApiCallCompleted {
                    id,
                    index: *index as u64,
                    actual_us: *actual_us,
                }
            }
            RequestEvent::Finished(completion) => {
                EventFrame::Finished(completion.wire_frame())
            }
            RequestEvent::Dropped { reason } => EventFrame::Dropped {
                id,
                reason,
            },
            RequestEvent::Error { message } => EventFrame::SessionError {
                id,
                error: message,
            },
        }
    }

    /// Render one protocol-v2 NDJSON frame. Every frame carries
    /// `type` and the session `id`.
    pub fn to_json(&self, id: u64) -> String {
        wire::Encoder::frame_to_string(&self.wire_frame(id))
    }
}

/// Where a session's events are delivered: `(session id, event)` pairs
/// pushed by the engine thread. One TCP connection fans all of its
/// sessions into a single sink; [`ServerHandle::open_session`] gives
/// each library session its own.
pub type EventSink = mpsc::Sender<(u64, RequestEvent)>;

enum Command {
    Open {
        spec: RequestSpec,
        sink: EventSink,
    },
    ToolResult {
        id: RequestId,
        index: usize,
        response_tokens: u64,
        /// Where to report an unknown-session rejection (the known-
        /// session path reports on the session's own sink). The TCP
        /// frontend passes its connection sink; library callers have
        /// none — their session stream either exists (and gets the
        /// Error event) or already closed with a terminal.
        reply: Option<EventSink>,
    },
    Shutdown,
}

/// Completion for a request the engine refused or abandoned: zero
/// `tokens_decoded` plus an explicit drop `reason`, and the client's
/// blocking recv is released instead of hanging forever.
fn dropped_completion(id: RequestId, reason: String) -> Completion {
    Completion {
        id: id.0,
        latency_us: 0,
        ttft_us: None,
        tokens_decoded: 0,
        generated: None,
        dropped: Some(reason),
    }
}

/// Handle to a running engine thread.
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Command>,
    next_id: Arc<AtomicU64>,
    /// The engine thread's API source, published once it boots (its
    /// config may be built inside the thread — PJRT handles are not
    /// `Send` — so the spawner cannot know it up front). The TCP
    /// frontend consults this to reject v1 one-shot requests whose
    /// API calls could never be resolved on an external-source
    /// server.
    api_source: Arc<std::sync::OnceLock<ApiSourceKind>>,
}

// mpsc::Sender is !Sync; guard clone-per-thread use behind a Mutex-free
// pattern: each connection thread clones the handle (Sender clones are
// cheap and Send).
impl ServerHandle {
    /// Open an event-streaming session for `spec` (its `id` and
    /// `arrival` are overwritten by the server). Events arrive on the
    /// returned handle from `Queued` through exactly one terminal
    /// `Finished`/`Dropped`.
    pub fn open_session(&self, spec: RequestSpec)
                        -> anyhow::Result<SessionHandle> {
        let (tx, rx) = mpsc::channel();
        let id = self.open_session_with(spec, tx)?;
        Ok(SessionHandle {
            id,
            server: self.clone(),
            events: rx,
        })
    }

    /// Low-level session open routing events into a caller-supplied
    /// sink — what lets one TCP connection serialize any number of
    /// concurrent sessions through one writer pump. Returns the
    /// assigned session id.
    pub fn open_session_with(&self, mut spec: RequestSpec,
                             sink: EventSink) -> anyhow::Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        spec.id = RequestId(id);
        self.tx
            .send(Command::Open { spec, sink })
            .map_err(|_| anyhow::anyhow!("server thread gone"))?;
        Ok(id)
    }

    /// Resolve session `id`'s externally-held API call `index` with
    /// the tool's actual response length (`tool_result` on the wire).
    /// Misdirected results (unknown id, wrong index, simulated call)
    /// are rejected by the engine and logged, never routed.
    pub fn complete_api_call(&self, id: u64, index: usize,
                             response_tokens: u64) -> anyhow::Result<()> {
        self.complete_api_call_with_reply(id, index, response_tokens,
                                          None)
    }

    /// [`ServerHandle::complete_api_call`] with a fallback sink for
    /// the unknown-session rejection (the TCP frontend's connection
    /// pump — a stale or typo'd id must come back as an error frame,
    /// not vanish into the server's stderr).
    fn complete_api_call_with_reply(&self, id: u64, index: usize,
                                    response_tokens: u64,
                                    reply: Option<EventSink>)
                                    -> anyhow::Result<()> {
        self.tx
            .send(Command::ToolResult {
                id: RequestId(id),
                index,
                response_tokens,
                reply,
            })
            .map_err(|_| anyhow::anyhow!("server thread gone"))
    }

    /// Submit a spec and block until completion — a thin
    /// drain-to-terminal wrapper over [`ServerHandle::open_session`].
    /// A dropped request yields a zero-token completion carrying the
    /// drop reason rather than an error. On an external-source server
    /// a spec with API calls must have its `tool_result`s posted from
    /// another thread, or this blocks until the call timeout drops the
    /// request (the v2 session API is the right tool there).
    pub fn submit_blocking(&self, spec: RequestSpec)
                           -> anyhow::Result<Completion> {
        self.open_session(spec)?.wait()
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Command::Shutdown);
    }

    /// The serving engine's API source, waiting (bounded, ~30 s) for
    /// the engine thread to publish it on boot — PJRT model loading
    /// inside the factory can take seconds. `None` means the engine
    /// has not booted (or died before publishing): callers must fail
    /// *closed* — e.g. reject a v1-with-API-calls line — never assume
    /// `Simulated`, which is exactly the guess that would deadlock
    /// the connection if wrong.
    fn api_source(&self) -> Option<ApiSourceKind> {
        for _ in 0..30_000 {
            if let Some(&kind) = self.api_source.get() {
                return Some(kind);
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        None
    }
}

/// One open session: a typed event stream plus the back-channel for
/// externally-resolved tool calls.
pub struct SessionHandle {
    id: u64,
    server: ServerHandle,
    events: mpsc::Receiver<(u64, RequestEvent)>,
}

impl SessionHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Next event, blocking. `None` once the stream is closed (the
    /// terminal event was already delivered, or the server is gone).
    pub fn next_event(&self) -> Option<RequestEvent> {
        self.events.recv().ok().map(|(_, ev)| ev)
    }

    /// Resolve this session's externally-held API call `index` with
    /// the tool's response length.
    pub fn complete_api_call(&self, index: usize, response_tokens: u64)
                             -> anyhow::Result<()> {
        self.server.complete_api_call(self.id, index, response_tokens)
    }

    /// Drain the stream to its terminal event — what
    /// [`ServerHandle::submit_blocking`] rides on. A session with
    /// externally-resolved calls cannot be drained this way unless
    /// another thread answers them.
    pub fn wait(self) -> anyhow::Result<Completion> {
        loop {
            match self.events.recv() {
                Ok((_, RequestEvent::Finished(completion))) => {
                    return Ok(completion);
                }
                Ok((id, RequestEvent::Dropped { reason })) => {
                    return Ok(dropped_completion(RequestId(id), reason));
                }
                Ok(_) => {}
                Err(_) => anyhow::bail!("server thread gone"),
            }
        }
    }
}

/// Backend + predictor pair for one engine replica (built inside the
/// engine thread — PJRT handles are not `Send`).
pub type ReplicaParts = (Box<dyn Backend>, Box<dyn Predictor>);

/// Spawn a simulated-backend server from a config alone — the frontend
/// counterpart of [`Engine::simulated`]. All engine knobs, including the
/// batch-composer settings (`cfg.compose`), multi-replica dispatch
/// (`cfg.replicas` + `cfg.placement`), and the API source
/// (`cfg.api_source`), take effect as-is.
pub fn spawn_sim(cfg: SystemConfig)
                 -> (ServerHandle, std::thread::JoinHandle<()>) {
    spawn_replicated(move || {
        let n = cfg.replicas.max(1);
        let parts: Vec<ReplicaParts> = (0..n)
            .map(|_| {
                (Box::new(crate::engine::backend::SimBackend::new(
                     cfg.cost)) as Box<dyn Backend>,
                 Box::new(crate::predictor::oracle::OraclePredictor)
                     as Box<dyn Predictor>)
            })
            .collect();
        (cfg, parts)
    })
}

/// Spawn a single-replica engine thread. PJRT handles are not `Send`,
/// so the caller provides a *factory* that constructs (config, backend,
/// predictor) inside the engine thread; both the sim and PJRT paths
/// share this frontend.
pub fn spawn<F>(factory: F) -> (ServerHandle, std::thread::JoinHandle<()>)
where
    F: FnOnce() -> (SystemConfig, Box<dyn Backend>, Box<dyn Predictor>)
        + Send
        + 'static,
{
    spawn_replicated(move || {
        let (cfg, backend, predictor) = factory();
        (cfg, vec![(backend, predictor)])
    })
}

/// Spawn the engine thread with one engine per replica part. Arriving
/// requests are routed through the configured placement policy
/// (`cfg.placement`); each session's events fan back in from whichever
/// replica owns the request. A request's KV state, swap traffic, and
/// API return all stay on its owning replica.
pub fn spawn_replicated<F>(factory: F)
                           -> (ServerHandle, std::thread::JoinHandle<()>)
where
    F: FnOnce() -> (SystemConfig, Vec<ReplicaParts>) + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<Command>();
    let api_source = Arc::new(std::sync::OnceLock::new());
    let handle = ServerHandle {
        tx,
        next_id: Arc::new(AtomicU64::new(0)),
        api_source: Arc::clone(&api_source),
    };
    let join = std::thread::spawn(move || {
        let (cfg, parts) = factory();
        let _ = api_source.set(cfg.api_source);
        engine_thread(cfg, parts, rx);
    });
    (handle, join)
}

/// Build the completion for a request the engine reported `Finished`.
fn build_completion(engine: &Engine, id: RequestId) -> Completion {
    let Some(r) = engine.request(id) else {
        // A finished id the engine no longer knows is a routing bug.
        // Answer the client with an explicit drop instead of tearing
        // down the whole connection thread on a panic.
        eprintln!("lamps-server: completion for unknown request {id}");
        return Completion {
            id: id.0,
            latency_us: 0,
            ttft_us: None,
            tokens_decoded: 0,
            generated: None,
            dropped: Some("server lost the request state".to_string()),
        };
    };
    #[cfg(feature = "pjrt")]
    let generated = engine.backend_any().and_then(|any| {
        any.downcast_ref::<crate::engine::pjrt_backend::PjrtBackend>()
            .and_then(|b| b.generated_tokens(id).map(|t| t.to_vec()))
    });
    #[cfg(not(feature = "pjrt"))]
    let generated = None;
    Completion {
        id: id.0,
        latency_us: r.finished_at.map_or_else(
            || {
                eprintln!(
                    "lamps-server: request {id} completed without a \
                     finish stamp"
                );
                0
            },
            |t| (t - r.spec.arrival).0,
        ),
        ttft_us: r.first_token_at.map(|t| (t - r.spec.arrival).0),
        tokens_decoded: r.spec.total_decode().0,
        generated,
        dropped: None,
    }
}

/// One session's server-side state: its event sink and the replica
/// that currently owns the request (updated by the admission
/// re-queue, so external returns and the completion always route to
/// the current owner).
struct Session {
    sink: EventSink,
    owner: usize,
}

fn engine_thread(cfg: SystemConfig, parts: Vec<ReplicaParts>,
                 rx: mpsc::Receiver<Command>) {
    assert!(!parts.is_empty(), "at least one replica required");
    // The index is useful only when the per-replica journals feed it:
    // Engine::new arms them on `cfg.replicas > 1`, so require that AND
    // a real multi-part fleet — the two can disagree through the public
    // `spawn_replicated` API, and a half-armed setup must read as "off"
    // (banner included) rather than silently never populating.
    let shared_on = cfg.shared_prefix && cfg.prefix_cache.enabled
        && cfg.replicas > 1 && parts.len() > 1;
    eprintln!(
        "lamps: engine up (scheduler {}, api source {}, replicas {} \
         [{} placement], batch composer: budget {}, prefill chunk {}, \
         async swap {}, prefix cache {}, shared prefix index {})",
        cfg.scheduler.label(),
        cfg.api_source.label(),
        parts.len(),
        cfg.placement.label(),
        cfg.compose
            .max_batch_tokens
            .map_or("unbounded".to_string(), |t| t.to_string()),
        if cfg.compose.auto_chunk {
            "auto".to_string()
        } else {
            cfg.compose
                .prefill_chunk
                .map_or("whole-context".to_string(), |t| t.to_string())
        },
        cfg.compose.async_swap,
        if cfg.prefix_cache.enabled {
            match cfg.prefix_cache.cache_blocks {
                Some(n) => format!("on (retain {n} blocks)"),
                None => "on (retain all)".to_string(),
            }
        } else {
            "off".to_string()
        },
        if shared_on { "on" } else { "off" });
    let placement = cfg.placement;
    // Fleet-level shared prefix index, mirrored from the per-replica
    // journals on the same cadence as the simulation driver (after each
    // engine step). Advisory only — the wall-clock loop may lag a step.
    let mut shared: Option<crate::cluster::SharedPrefixIndex> =
        shared_on.then(crate::cluster::SharedPrefixIndex::new);
    let mut engines: Vec<Engine> = parts
        .into_iter()
        .map(|(backend, predictor)| {
            let mut engine = Engine::new(cfg.clone(), backend, predictor,
                                         Clock::wall_clock());
            // Session streams are fed from the engines' lifecycle
            // journals (drained every pass below).
            engine.enable_events();
            engine
        })
        .collect();
    let mut rr_next = 0usize;
    let mut sessions: std::collections::HashMap<RequestId, Session> =
        std::collections::HashMap::new();
    // Requests the admission re-queue already moved once (see below).
    let mut requeued: std::collections::HashSet<RequestId> =
        std::collections::HashSet::new();
    let mut shutdown = false;
    // lamps-lint: allow(wall-clock) the timeout sweep tracks real elapsed client time
    let mut last_timeout_sweep = std::time::Instant::now();
    // Event-pump scratch, reused across passes: the journal drain swaps
    // buffers with each engine (`drain_events_into`), so a busy pump
    // ping-pongs the same allocations forever instead of allocating a
    // fresh Vec per engine per pass.
    let mut journaled: Vec<(usize, EngineEvent)> = Vec::new();
    let mut drained: Vec<EngineEvent> = Vec::new();

    loop {
        // Drain commands without blocking.
        loop {
            match rx.try_recv() {
                Ok(Command::Open { mut spec, sink }) => {
                    let block_size = engines
                        .first()
                        .map_or(1, |e| e.cfg.block_size)
                        .max(1);
                    let arrival = crate::cluster::ArrivalScratch::new(
                        &spec, block_size);
                    let (r, _credit) = crate::cluster::pick_replica(
                        &engines, placement, &mut rr_next, &arrival,
                        shared.as_ref());
                    let chain = arrival.into_chain();
                    // lamps-lint: allow(panic) pick_replica returns an in-range index
                    spec.arrival = engines[r].now();
                    let id = spec.id;
                    if let Some(chain) = chain {
                        // Placement hashed the prompt once; the owner
                        // extends the chain instead of rehashing it.
                        // lamps-lint: allow(panic) pick_replica returns an in-range index
                        engines[r].seed_chain(id, block_size, chain);
                    }
                    let _ = sink.send((id.0, RequestEvent::Queued));
                    let _ = sink.send((id.0, RequestEvent::Placed {
                        replica: r,
                    }));
                    sessions.insert(id, Session { sink, owner: r });
                    // lamps-lint: allow(panic) pick_replica returns an in-range index
                    engines[r].submit(spec);
                }
                Ok(Command::ToolResult {
                    id,
                    index,
                    response_tokens,
                    reply,
                }) => {
                    // External returns route to the request's *current*
                    // owner — a rescue may have moved it since
                    // placement. A result the engine refuses (wrong
                    // index, duplicate fire, simulated call) is
                    // reported back on the session's stream as a
                    // non-terminal Error event — silence would leave
                    // the client believing the call resolved while it
                    // stays parked.
                    match sessions.get(&id) {
                        Some(session) => {
                            // lamps-lint: allow(panic) session.owner tracks a valid replica index
                            if let Err(e) = engines[session.owner]
                                .complete_api_call(
                                    id, index, Tokens(response_tokens))
                            {
                                let _ = session.sink.send((
                                    id.0,
                                    RequestEvent::Error {
                                        message: format!(
                                            "tool_result rejected: {e}"),
                                    },
                                ));
                            }
                        }
                        None => {
                            let message = format!(
                                "tool_result for unknown session {id} \
                                 (already finished, dropped, or never \
                                 opened)");
                            match reply {
                                Some(sink) => {
                                    let _ = sink.send((
                                        id.0,
                                        RequestEvent::Error { message },
                                    ));
                                }
                                None => {
                                    eprintln!("lamps: {message}");
                                }
                            }
                        }
                    }
                }
                Ok(Command::Shutdown) => shutdown = true,
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }

        // A shutdown request ends the service: outstanding
        // externally-held calls can never be resolved once the
        // operator asked to stop, and waiting out the 10-minute
        // client backstop would hang anything joining the engine
        // thread — abort them now, so shutdown is bounded by the poll
        // cadence (their sessions close with Dropped below).
        if shutdown {
            for engine in engines.iter_mut() {
                for id in engine.external_api_ids() {
                    engine.abort_external_call(
                        id, "server shutting down".to_string());
                }
            }
        }

        // Abort externally-held calls nobody will ever answer (client
        // gone, tool hung): past EXTERNAL_CALL_TIMEOUT the owning
        // engine drops the request terminally and the resulting
        // Dropped event closes the session — releasing the pinned KV,
        // the once-only re-queue guard, and (once no sink remains) the
        // connection's writer pump.
        if last_timeout_sweep.elapsed() >= TIMEOUT_SWEEP_PERIOD {
            // lamps-lint: allow(wall-clock) the timeout sweep tracks real elapsed client time
            last_timeout_sweep = std::time::Instant::now();
            // Scan the engines' own externally-parked sets, NOT the
            // session map: a request orphaned mid-decode (dead sink
            // detached its session) can still park on an external
            // call afterwards, and it must be swept too or it pins
            // its KV forever.
            for engine in engines.iter_mut() {
                let now = engine.now();
                for id in engine.external_api_ids() {
                    let expired = engine.request(id).is_some_and(|r| {
                        r.api_started_at.is_some_and(
                            |t0| now - t0 >= EXTERNAL_CALL_TIMEOUT)
                    });
                    if expired {
                        engine.abort_external_call(
                            id,
                            format!("external API call unresolved \
                                     after {}s",
                                    EXTERNAL_CALL_TIMEOUT.0
                                        / 1_000_000));
                    }
                }
            }
        }

        let mut progressed = false;
        // Orphaned *runnable* requests (their session's client hung
        // up mid-decode) still drain via `has_runnable_work`; orphaned
        // *parked* external calls are bounded by the timeout sweep
        // above — so a long-running server never strands engine state
        // behind a dead sink for more than EXTERNAL_CALL_TIMEOUT.
        let active = !sessions.is_empty()
            || engines.iter().any(|e| e.has_runnable_work());
        if active {
            for (i, engine) in engines.iter_mut().enumerate() {
                if !engine.has_live_work() {
                    continue;
                }
                engine.set_external_event(None);
                let next = engine.next_event_time();
                // An engine with nothing runnable and only a future
                // event is left alone entirely — the single poll sleep
                // at the bottom of the loop covers it; stepping it
                // would add one serialized in-step sleep per idle
                // replica per pass. An engine whose only in-flight work
                // is an externally-held API call has no event at all —
                // `next_return` does not bound that wait — and is
                // likewise left alone until the tool result lands.
                let due = next.is_some_and(|t| t <= engine.now());
                if !due && !engine.has_runnable_work() {
                    continue;
                }
                // Runnable engines can still hit the idle branch
                // (waiting requests blocked on memory held through an
                // API call): bound that wall-clock wait to one poll
                // tick so it cannot stall sibling replicas or command
                // draining. The hint never delays a due event (the
                // idle jump takes the earliest), and no synthetic
                // event is injected when the engine has none at all,
                // so the idle-path preemption fallback stays
                // reachable.
                let hint =
                    next.map(|t| t.min(engine.now() + POLL_TICK));
                engine.set_external_event(hint);
                progressed |= engine.step();
                // Mirror this replica's prefix-cache deltas into the
                // fleet index. Drained unconditionally so an armed
                // journal can never grow without bound.
                let deltas = engine.drain_prefix_deltas();
                if let Some(index) = shared.as_mut() {
                    for delta in &deltas {
                        index.on_delta(i, delta);
                    }
                }
            }
            // Placement-aware admission re-queue, sharing the
            // simulated fleet's protocol core
            // (`cluster::rescue_stranded_on`): a request
            // memory-rejected by its owner before first run moves once
            // to the best sibling that can admit it now; its session
            // follows so later events (and the external-return route)
            // come from the new owner.
            if cfg.admission_requeue && engines.len() > 1 {
                for owner in 0..engines.len() {
                    let moves = crate::cluster::rescue_stranded_on(
                        &mut engines, owner, placement,
                        shared.as_ref(), &mut requeued);
                    for (id, j, _credit) in moves {
                        if let Some(session) = sessions.get_mut(&id) {
                            let _ = session.sink.send((
                                id.0,
                                RequestEvent::Rescued {
                                    from: owner,
                                    to: j,
                                },
                            ));
                            session.owner = j;
                        }
                        progressed = true;
                    }
                }
            }
        }

        // Forward the engines' journaled lifecycle events onto their
        // sessions' streams. Terminal events close the session (and
        // retire its once-only re-queue guard — a long-running server
        // must not accumulate one per rescued request forever);
        // non-terminal events whose sink is gone detach the session so
        // the request finishes as an orphan.
        journaled.clear();
        for (i, engine) in engines.iter_mut().enumerate() {
            engine.drain_events_into(&mut drained);
            for ev in drained.drain(..) {
                journaled.push((i, ev));
            }
        }
        for (replica, ev) in journaled.drain(..) {
            let (id, event) = match ev {
                EngineEvent::FirstToken { id, .. } => {
                    (id, RequestEvent::FirstToken)
                }
                EngineEvent::Tokens { id, chunk } => {
                    (id, RequestEvent::Tokens { chunk })
                }
                EngineEvent::ApiStarted {
                    id,
                    index,
                    strategy,
                    predicted,
                    external,
                } => (id, RequestEvent::ApiCallStarted {
                    index,
                    strategy,
                    predicted_us: predicted.0,
                    external,
                }),
                EngineEvent::ApiCompleted { id, index, actual } => {
                    (id, RequestEvent::ApiCallCompleted {
                        index,
                        actual_us: actual.0,
                    })
                }
                EngineEvent::Finished { id, .. } => {
                    (id, RequestEvent::Finished(
                        // lamps-lint: allow(panic) session.owner tracks a valid replica index
                        build_completion(&engines[replica], id)))
                }
                EngineEvent::Dropped { id, reason } => {
                    (id, RequestEvent::Dropped { reason })
                }
            };
            if event.is_terminal() {
                requeued.remove(&id);
                if let Some(session) = sessions.remove(&id) {
                    let _ = session.sink.send((id.0, event));
                }
            } else {
                let sink_dead = match sessions.get(&id) {
                    Some(session) => {
                        session.sink.send((id.0, event)).is_err()
                    }
                    None => false,
                };
                if sink_dead {
                    sessions.remove(&id);
                }
            }
        }

        if shutdown && sessions.is_empty() {
            return;
        }
        if !progressed {
            std::thread::sleep(Duration::from_micros(POLL_TICK.0));
        }
    }
}

/// One API call of a wire request (protocol v2 `api_calls` entry) —
/// the typed [`crate::wire::CallFrame`], re-exported under the name
/// this module has always used.
pub type WireCall = wire::CallFrame;

/// A request line of the JSON wire protocol (v2 `api_calls` array, or
/// the legacy v1 `pre_api_tokens`/`api_ms` single-call shape), with
/// the prompt owned so it can outlive the connection read buffer.
#[derive(Debug, Clone)]
pub struct WireRequest {
    pub prompt: String,
    pub api_calls: Vec<WireCall>,
    pub output_tokens: u64,
}

impl From<wire::RequestFrame<'_>> for WireRequest {
    fn from(frame: wire::RequestFrame<'_>) -> Self {
        WireRequest {
            prompt: frame.prompt.into_owned(),
            api_calls: frame.api_calls,
            output_tokens: frame.output_tokens,
        }
    }
}

impl WireRequest {
    /// Parse a request line (v1 or v2) through the zero-copy
    /// [`crate::wire::Frame`] lexer, taking ownership of the decoded
    /// strings. Non-request frame types are rejected.
    pub fn parse(line: &str) -> anyhow::Result<WireRequest> {
        match wire::Frame::parse(line) {
            Ok(wire::Frame::Request(req))
            | Ok(wire::Frame::V1Request(req)) => Ok(req.into()),
            Ok(_) => anyhow::bail!("not a request frame"),
            Err(e) => Err(e.into()),
        }
    }

    pub fn to_spec(&self) -> RequestSpec {
        use crate::core::request::ApiCallSpec;
        let prompt_tokens =
            crate::util::tokenizer::valid_len(&self.prompt, 64) as u64;
        let api_calls = self
            .api_calls
            .iter()
            .map(|call| ApiCallSpec {
                decode_before: Tokens(call.decode_before),
                api_type: call.api_type,
                duration: call.api_ms.map(|ms| Micros(ms * 1000))
                    .unwrap_or_else(|| {
                        crate::predictor::duration::class_prior_duration(
                            call.api_type)
                    }),
                response_tokens: Tokens(call.response_tokens),
            })
            .collect();
        RequestSpec {
            id: RequestId(0), // assigned by the server
            arrival: Micros::ZERO,
            prompt: self.prompt.clone(),
            prompt_tokens: Tokens(prompt_tokens),
            api_calls,
            final_decode: Tokens(self.output_tokens.max(1)),
        }
    }
}

/// Serve the JSON-lines wire protocol over TCP (one frame per line,
/// both directions — see the module docs for the v2 schema). Blocks
/// forever.
pub fn serve_tcp(handle: ServerHandle, addr: &str) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("lamps: serving on {addr}");
    let handle = Arc::new(Mutex::new(handle));
    for stream in listener.incoming() {
        let stream = stream?;
        let handle = {
            // A panicked holder only ever cloned the handle; the
            // data cannot be torn, so recover the guard.
            let guard = handle
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            guard.clone()
        };
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, handle) {
                eprintln!("lamps: connection error: {e}");
            }
        });
    }
    Ok(())
}

/// Handle one inbound line, pushing any immediate reply frames (v1
/// completions and error frames — v2 session output flows through the
/// event pump instead) onto the connection's reusable reply encoder.
fn dispatch_line(line: &str, handle: &ServerHandle, events: &EventSink,
                 reply: &mut wire::Encoder) {
    let frame = match wire::Frame::parse(line) {
        Ok(frame) => frame,
        Err(e) => {
            reply.push(&EventFrame::Error {
                error: &e.reply_message(),
            });
            return;
        }
    };
    match frame {
        // Legacy v1: no type field, one blocking completion per line.
        wire::Frame::V1Request(req) => {
            // A v1 one-shot with API calls would block this reader
            // thread inside submit_blocking waiting for a tool_result
            // that can never arrive on an external-source server (the
            // v1 client is never told the session id, and the blocked
            // reader would stop consuming lines for the whole
            // connection) — reject it up front instead of
            // deadlocking. Fail closed while the engine is still
            // booting (api_source unknown): wrongly guessing
            // `Simulated` here is precisely the deadlock.
            if !req.api_calls.is_empty()
                && handle.api_source() != Some(ApiSourceKind::Simulated)
            {
                reply.push(&EventFrame::Error {
                    error:
                        "v1 one-shot requests cannot carry API calls \
                         on an external-source (or still-booting) \
                         server; open a v2 session with \
                         {\"type\":\"request\",...}",
                });
                return;
            }
            let req = WireRequest::from(req);
            match handle.submit_blocking(req.to_spec()) {
                Ok(completion) => reply.push(
                    &EventFrame::Completion(completion.wire_frame())),
                Err(e) => reply.push(&EventFrame::Error {
                    error: &e.to_string(),
                }),
            }
        }
        wire::Frame::Request(req) => {
            let req = WireRequest::from(req);
            // The `queued` frame announces the session id; only a
            // failed open is answered here.
            if let Err(e) =
                handle.open_session_with(req.to_spec(), events.clone())
            {
                reply.push(&EventFrame::Error {
                    error: &e.to_string(),
                });
            }
        }
        wire::Frame::ToolResult(tr) => {
            if let Err(e) = handle.complete_api_call_with_reply(
                tr.id, tr.index as usize, tr.response_tokens,
                Some(events.clone()))
            {
                reply.push(&EventFrame::Error {
                    error: &format!("bad tool_result: {e}"),
                });
            }
        }
        // Reserved: parse + acknowledge, but don't tear anything down
        // — see the module docs.
        wire::Frame::Cancel(c) => {
            reply.push(&EventFrame::SessionError {
                id: c.id,
                error: "cancel is reserved but not yet implemented; \
                        the session keeps streaming",
            });
        }
    }
}

fn handle_conn(stream: TcpStream, handle: ServerHandle)
               -> anyhow::Result<()> {
    let peer = stream.peer_addr()?;
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let mut frames = FrameReader::new(BufReader::new(stream));
    // One pump serializes every session's event frames onto the
    // socket; the reader thread writes only immediate replies (v1
    // completions, error frames) under the same lock. The pump owns a
    // reusable encoder: block for the first event, fold every
    // already-queued event into the same buffer (bounded by
    // PUMP_DRAIN_BYTES), encode outside the writer lock, then flush
    // the whole batch with one write.
    let (ev_tx, ev_rx) = mpsc::channel::<(u64, RequestEvent)>();
    let pump_writer = Arc::clone(&writer);
    let pump = std::thread::spawn(move || {
        let mut enc = wire::Encoder::with_capacity(4096);
        while let Ok((id, ev)) = ev_rx.recv() {
            enc.push(&ev.wire_frame(id));
            while enc.len() < PUMP_DRAIN_BYTES {
                match ev_rx.try_recv() {
                    Ok((id, ev)) => enc.push(&ev.wire_frame(id)),
                    Err(_) => break,
                }
            }
            let mut w = pump_writer
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            if enc.drain_to(&mut *w).is_err() {
                // Client gone: the engine thread detaches the sessions
                // on its next failed send.
                return;
            }
        }
    });
    // Immediate replies reuse one encoder for the connection's
    // lifetime; inbound lines are borrowed straight out of the read
    // buffer (zero-copy unless a string field contains escapes).
    let mut reply = wire::Encoder::new();
    while let Some(next) = frames.next_line()? {
        match next {
            WireLine::Oversized { bytes } => {
                // The line was discarded while reading — answer with a
                // well-formed error frame and stay alive (the reader
                // already resynchronized on the newline).
                reply.push(&EventFrame::Error {
                    error: &format!(
                        "bad request: frame of {bytes} bytes exceeds \
                         the {} byte frame cap",
                        wire::MAX_FRAME_BYTES),
                });
            }
            WireLine::Frame(raw) => match std::str::from_utf8(raw) {
                // Pre-wire servers tore the connection down here; an
                // error frame keeps the (well-tested) listener
                // invariant that every inbound line gets JSON or
                // nothing, never a hangup mid-protocol.
                Err(_) => reply.push(&EventFrame::Error {
                    error: "bad request: frame is not valid UTF-8",
                }),
                Ok(line) => {
                    if line.trim().is_empty() {
                        continue;
                    }
                    dispatch_line(line, &handle, &ev_tx, &mut reply);
                }
            },
        }
        if !reply.is_empty() {
            let mut w = writer
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            reply.drain_to(&mut *w)?;
        }
    }
    // Half-close: the client stopped sending, but open sessions keep
    // streaming until their terminal events land (the pump exits once
    // every session sink is dropped).
    drop(ev_tx);
    let _ = pump.join();
    eprintln!("lamps: {peer} disconnected");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::ApiType;

    #[test]
    fn wire_request_parse_v1_full() {
        let r = WireRequest::parse(
            r#"{"prompt": "hi there", "output_tokens": 12,
                "pre_api_tokens": 4, "api_ms": 50}"#).unwrap();
        assert_eq!(r.output_tokens, 12);
        assert_eq!(r.api_calls.len(), 1);
        assert_eq!(r.api_calls[0].decode_before, 4);
        let spec = r.to_spec();
        assert_eq!(spec.api_calls.len(), 1);
        assert_eq!(spec.api_calls[0].duration, Micros(50_000));
        assert_eq!(spec.api_calls[0].response_tokens, Tokens(4));
        assert_eq!(spec.final_decode.0, 12);
    }

    #[test]
    fn wire_request_defaults() {
        let r = WireRequest::parse(
            r#"{"prompt": "x", "output_tokens": 3}"#).unwrap();
        assert!(r.api_calls.is_empty());
        assert!(r.to_spec().api_calls.is_empty());
    }

    #[test]
    fn wire_request_parse_v2_multi_call() {
        let r = WireRequest::parse(
            r#"{"type": "request", "prompt": "plan my trip",
                "output_tokens": 20,
                "api_calls": [
                  {"decode_before": 5, "api_type": "qa", "api_ms": 700,
                   "response_tokens": 32},
                  {"decode_before": 3, "api_type": "image"},
                  {"decode_before": 2}
                ]}"#).unwrap();
        assert_eq!(r.api_calls.len(), 3);
        let spec = r.to_spec();
        assert_eq!(spec.api_calls[0].duration, Micros(700_000));
        assert_eq!(spec.api_calls[0].response_tokens, Tokens(32));
        // No api_ms: the class's Table 2 mean is the duration (and the
        // oracle's prediction).
        assert_eq!(spec.api_calls[1].duration,
                   crate::predictor::duration::class_prior_duration(
                       ApiType::Image));
        assert_eq!(spec.api_calls[1].response_tokens, Tokens(4));
        // No api_type: the generic tool class.
        assert_eq!(spec.api_calls[2].api_type, ApiType::Tool(0));
        // Three calls -> four segments.
        assert_eq!(spec.num_segments(), 4);
    }

    #[test]
    fn wire_request_rejects_missing_fields_and_bad_calls() {
        assert!(WireRequest::parse(r#"{"prompt": "x"}"#).is_err());
        assert!(WireRequest::parse("not json").is_err());
        assert!(WireRequest::parse(
            r#"{"prompt": "x", "output_tokens": 1,
                "api_calls": 3}"#).is_err());
        assert!(WireRequest::parse(
            r#"{"prompt": "x", "output_tokens": 1,
                "api_calls": [{"decode_before": 1,
                               "api_type": "nope"}]}"#).is_err());
        assert!(WireRequest::parse(
            r#"{"prompt": "x", "output_tokens": 1,
                "api_calls": [{"api_type": "qa"}]}"#).is_err(),
                "decode_before is required per call");
    }

    #[test]
    fn completion_json_shape() {
        let c = Completion {
            id: 3,
            latency_us: 1000,
            ttft_us: Some(10),
            tokens_decoded: 5,
            generated: Some(vec![1, 2]),
            dropped: None,
        };
        let v = json::parse(&c.to_json()).unwrap();
        assert_eq!(v.u64_field("id").unwrap(), 3);
        assert_eq!(v.get("generated").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("dropped").is_none(),
                "served completions carry no dropped key");
        let c2 = Completion {
            ttft_us: None,
            generated: None,
            ..c
        };
        let v2 = json::parse(&c2.to_json()).unwrap();
        assert_eq!(v2.get("ttft_us"), Some(&Value::Null));
        // A dropped completion is distinguishable from a zero-token
        // serve: the reason rides in the JSON.
        let d = dropped_completion(RequestId(9),
                                   "context outgrew budget".to_string());
        let vd = json::parse(&d.to_json()).unwrap();
        assert_eq!(vd.u64_field("tokens_decoded").unwrap(), 0);
        assert_eq!(vd.str_field("dropped").unwrap(),
                   "context outgrew budget");
    }

    #[test]
    fn event_frames_are_valid_json() {
        let events = vec![
            RequestEvent::Queued,
            RequestEvent::Placed { replica: 2 },
            RequestEvent::Rescued { from: 2, to: 0 },
            RequestEvent::FirstToken,
            RequestEvent::Tokens { chunk: 7 },
            RequestEvent::ApiCallStarted {
                index: 0,
                strategy: HandlingStrategy::Swap,
                predicted_us: 690_000,
                external: true,
            },
            RequestEvent::ApiCallCompleted {
                index: 0,
                actual_us: 1_234,
            },
            RequestEvent::Finished(Completion {
                id: 5,
                latency_us: 10,
                ttft_us: None,
                tokens_decoded: 1,
                generated: None,
                dropped: None,
            }),
            RequestEvent::Dropped {
                reason: "a \"quoted\" \\ reason".to_string(),
            },
            RequestEvent::Error {
                message: "tool_result rejected: wrong index"
                    .to_string(),
            },
        ];
        let mut terminals = 0;
        for ev in &events {
            let frame = ev.to_json(5);
            let v = json::parse(&frame).expect("frame must be JSON");
            assert_eq!(v.u64_field("id").unwrap(), 5, "{frame}");
            assert!(v.str_field("type").is_ok(), "{frame}");
            if ev.is_terminal() {
                terminals += 1;
            }
        }
        assert_eq!(terminals, 2);
        // Spot-check the api_call_started payload.
        let started = events[5].to_json(5);
        let v = json::parse(&started).unwrap();
        assert_eq!(v.str_field("type").unwrap(), "api_call_started");
        assert_eq!(v.str_field("strategy").unwrap(), "swap");
        assert_eq!(v.u64_field("predicted_us").unwrap(), 690_000);
        assert_eq!(v.get("external").unwrap().as_bool(), Some(true));
        // Injection-proof: the dropped reason survives a round-trip.
        let dropped = events[8].to_json(5);
        let v = json::parse(&dropped).unwrap();
        assert_eq!(v.str_field("reason").unwrap(),
                   "a \"quoted\" \\ reason");
    }

    #[test]
    fn error_frames_are_injection_proof() {
        // The old format! splice emitted invalid/forgeable JSON when
        // the error text contained quotes or backslashes.
        let hostile = "boom\" ,\"tokens_decoded\":999,\"x\":\"\\";
        let frame = wire::Encoder::frame_to_string(
            &EventFrame::Error { error: hostile });
        let v = json::parse(&frame).expect("must stay valid JSON");
        assert_eq!(v.str_field("error").unwrap(), hostile);
        assert_eq!(v.str_field("type").unwrap(), "error");
        assert!(v.get("tokens_decoded").is_none(), "no forged fields");
    }
}
