//! Serving frontend: a dedicated engine thread in wall-clock mode, fed
//! through a channel; clients block on a per-request completion channel.
//! A JSON-lines TCP listener (`serve_tcp`) exposes the same API over the
//! network for the quickstart example.
//!
//! (The offline vendor set has no tokio; the frontend is std-thread based.
//! Each TCP connection gets its own handler thread — adequate for the
//! demo-scale deployments this CPU image can serve.)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::cluster::PrefixDeltaSink;
use crate::config::SystemConfig;
use crate::core::request::RequestSpec;
use crate::core::types::{Micros, RequestId};
use crate::engine::backend::Backend;
use crate::engine::clock::Clock;
use crate::engine::Engine;
use crate::predictor::Predictor;
use crate::util::json::{self, Value};

/// Idle poll period of the engine thread — also the cap on how long one
/// replica's in-step wall-clock wait may stall the shared loop.
const POLL_TICK: Micros = Micros(200);

/// What the client receives when its request finishes.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub latency_us: u64,
    pub ttft_us: Option<u64>,
    pub tokens_decoded: u64,
    /// Real model outputs when the engine runs on the PJRT backend.
    pub generated: Option<Vec<i32>>,
}

impl Completion {
    pub fn to_json(&self) -> String {
        let mut pairs = vec![
            ("id", json::num(self.id as f64)),
            ("latency_us", json::num(self.latency_us as f64)),
            ("tokens_decoded", json::num(self.tokens_decoded as f64)),
        ];
        pairs.push(("ttft_us", match self.ttft_us {
            Some(t) => json::num(t as f64),
            None => Value::Null,
        }));
        pairs.push(("generated", match &self.generated {
            Some(toks) => Value::Arr(
                toks.iter().map(|t| json::num(*t as f64)).collect()),
            None => Value::Null,
        }));
        json::write(&json::obj(pairs))
    }
}

enum Command {
    Submit {
        spec: RequestSpec,
        done: mpsc::Sender<Completion>,
    },
    Shutdown,
}

/// Completion for a request the engine refused or abandoned (it can
/// never fit its replica's memory budget): zero `tokens_decoded` marks
/// it unserved, and the client's blocking recv is released instead of
/// hanging forever.
fn dropped_completion(id: RequestId) -> Completion {
    Completion {
        id: id.0,
        latency_us: 0,
        ttft_us: None,
        tokens_decoded: 0,
        generated: None,
    }
}

/// Handle to a running engine thread.
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Command>,
    next_id: Arc<AtomicU64>,
}

// mpsc::Sender is !Sync; guard clone-per-thread use behind a Mutex-free
// pattern: each connection thread clones the handle (Sender clones are
// cheap and Send).
impl ServerHandle {
    /// Submit a spec and block until completion. The spec's `id` and
    /// `arrival` are overwritten by the server.
    pub fn submit_blocking(&self, mut spec: RequestSpec)
                           -> anyhow::Result<Completion> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        spec.id = RequestId(id);
        let (done_tx, done_rx) = mpsc::channel();
        self.tx
            .send(Command::Submit {
                spec,
                done: done_tx,
            })
            .map_err(|_| anyhow::anyhow!("server thread gone"))?;
        Ok(done_rx.recv()?)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Command::Shutdown);
    }
}

/// Backend + predictor pair for one engine replica (built inside the
/// engine thread — PJRT handles are not `Send`).
pub type ReplicaParts = (Box<dyn Backend>, Box<dyn Predictor>);

/// Spawn a simulated-backend server from a config alone — the frontend
/// counterpart of [`Engine::simulated`]. All engine knobs, including the
/// batch-composer settings (`cfg.compose`) and multi-replica dispatch
/// (`cfg.replicas` + `cfg.placement`), take effect as-is.
pub fn spawn_sim(cfg: SystemConfig)
                 -> (ServerHandle, std::thread::JoinHandle<()>) {
    spawn_replicated(move || {
        let n = cfg.replicas.max(1);
        let parts: Vec<ReplicaParts> = (0..n)
            .map(|_| {
                (Box::new(crate::engine::backend::SimBackend::new(
                     cfg.cost)) as Box<dyn Backend>,
                 Box::new(crate::predictor::oracle::OraclePredictor)
                     as Box<dyn Predictor>)
            })
            .collect();
        (cfg, parts)
    })
}

/// Spawn a single-replica engine thread. PJRT handles are not `Send`,
/// so the caller provides a *factory* that constructs (config, backend,
/// predictor) inside the engine thread; both the sim and PJRT paths
/// share this frontend.
pub fn spawn<F>(factory: F) -> (ServerHandle, std::thread::JoinHandle<()>)
where
    F: FnOnce() -> (SystemConfig, Box<dyn Backend>, Box<dyn Predictor>)
        + Send
        + 'static,
{
    spawn_replicated(move || {
        let (cfg, backend, predictor) = factory();
        (cfg, vec![(backend, predictor)])
    })
}

/// Spawn the engine thread with one engine per replica part. Arriving
/// requests are routed through the configured placement policy
/// (`cfg.placement`); completions fan back in from whichever replica
/// owns the request. A request's KV state, swap traffic, and API return
/// all stay on its owning replica.
pub fn spawn_replicated<F>(factory: F)
                           -> (ServerHandle, std::thread::JoinHandle<()>)
where
    F: FnOnce() -> (SystemConfig, Vec<ReplicaParts>) + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<Command>();
    let handle = ServerHandle {
        tx,
        next_id: Arc::new(AtomicU64::new(0)),
    };
    let join = std::thread::spawn(move || {
        let (cfg, parts) = factory();
        engine_thread(cfg, parts, rx);
    });
    (handle, join)
}

fn engine_thread(cfg: SystemConfig, parts: Vec<ReplicaParts>,
                 rx: mpsc::Receiver<Command>) {
    assert!(!parts.is_empty(), "at least one replica required");
    // The index is useful only when the per-replica journals feed it:
    // Engine::new arms them on `cfg.replicas > 1`, so require that AND
    // a real multi-part fleet — the two can disagree through the public
    // `spawn_replicated` API, and a half-armed setup must read as "off"
    // (banner included) rather than silently never populating.
    let shared_on = cfg.shared_prefix && cfg.prefix_cache.enabled
        && cfg.replicas > 1 && parts.len() > 1;
    eprintln!(
        "lamps: engine up (scheduler {}, replicas {} [{} placement], \
         batch composer: budget {}, prefill chunk {}, async swap {}, \
         prefix cache {}, shared prefix index {})",
        cfg.scheduler.label(),
        parts.len(),
        cfg.placement.label(),
        cfg.compose
            .max_batch_tokens
            .map_or("unbounded".to_string(), |t| t.to_string()),
        cfg.compose
            .prefill_chunk
            .map_or("whole-context".to_string(), |t| t.to_string()),
        cfg.compose.async_swap,
        if cfg.prefix_cache.enabled {
            match cfg.prefix_cache.cache_blocks {
                Some(n) => format!("on (retain {n} blocks)"),
                None => "on (retain all)".to_string(),
            }
        } else {
            "off".to_string()
        },
        if shared_on { "on" } else { "off" });
    let placement = cfg.placement;
    // Fleet-level shared prefix index, mirrored from the per-replica
    // journals on the same cadence as the simulation driver (after each
    // engine step). Advisory only — the wall-clock loop may lag a step.
    let mut shared: Option<crate::cluster::SharedPrefixIndex> =
        shared_on.then(crate::cluster::SharedPrefixIndex::new);
    let mut engines: Vec<Engine> = parts
        .into_iter()
        .map(|(backend, predictor)| {
            Engine::new(cfg.clone(), backend, predictor,
                        Clock::wall_clock())
        })
        .collect();
    let mut rr_next = 0usize;
    // (request, owning replica, completion channel)
    let mut watchers: Vec<(RequestId, usize, mpsc::Sender<Completion>)> =
        Vec::new();
    // Requests the admission re-queue already moved once (see below).
    let mut requeued: std::collections::HashSet<RequestId> =
        std::collections::HashSet::new();
    let mut shutdown = false;

    loop {
        // Drain commands without blocking.
        loop {
            match rx.try_recv() {
                Ok(Command::Submit { mut spec, done }) => {
                    let (r, _credit) = crate::cluster::pick_replica(
                        &engines, placement, &mut rr_next, &spec,
                        shared.as_ref());
                    spec.arrival = engines[r].now();
                    let id = spec.id;
                    engines[r].submit(spec);
                    watchers.push((id, r, done));
                }
                Ok(Command::Shutdown) => shutdown = true,
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }

        let mut progressed = false;
        if !watchers.is_empty() {
            for (i, engine) in engines.iter_mut().enumerate() {
                if !engine.has_live_work() {
                    continue;
                }
                engine.set_external_event(None);
                let next = engine.next_event_time();
                // An engine with nothing runnable and only a future
                // event is left alone entirely — the single poll sleep
                // at the bottom of the loop covers it; stepping it
                // would add one serialized in-step sleep per idle
                // replica per pass.
                let due = next.is_some_and(|t| t <= engine.now());
                if !due && !engine.has_runnable_work() {
                    continue;
                }
                // Runnable engines can still hit the idle branch
                // (waiting requests blocked on memory held through an
                // API call): bound that wall-clock wait to one poll
                // tick so it cannot stall sibling replicas or command
                // draining. The hint never delays a due event (the
                // idle jump takes the earliest), and no synthetic
                // event is injected when the engine has none at all,
                // so the idle-path preemption fallback stays
                // reachable.
                let hint =
                    next.map(|t| t.min(engine.now() + POLL_TICK));
                engine.set_external_event(hint);
                progressed |= engine.step();
                // Mirror this replica's prefix-cache deltas into the
                // fleet index. Drained unconditionally so an armed
                // journal can never grow without bound.
                let deltas = engine.drain_prefix_deltas();
                if let Some(index) = shared.as_mut() {
                    for delta in &deltas {
                        index.on_delta(i, delta);
                    }
                }
            }
            // Placement-aware admission re-queue, sharing the
            // simulated fleet's protocol core
            // (`cluster::rescue_stranded_on`): a request
            // memory-rejected by its owner before first run moves once
            // to the best sibling that can admit it now; its watcher
            // follows so the completion fans in from the new owner.
            if cfg.admission_requeue && engines.len() > 1 {
                for owner in 0..engines.len() {
                    let moves = crate::cluster::rescue_stranded_on(
                        &mut engines, owner, placement,
                        shared.as_ref(), &mut requeued);
                    for (id, j, _credit) in moves {
                        for w in watchers.iter_mut() {
                            if w.0 == id {
                                w.1 = j;
                            }
                        }
                        progressed = true;
                    }
                }
            }
        }

        // Notify completions from each request's owning replica.
        let mut still: Vec<(RequestId, usize,
                            mpsc::Sender<Completion>)> = Vec::new();
        for (id, owner, done) in watchers.drain(..) {
            let engine = &engines[owner];
            let Some(r) = engine.request(id) else {
                // Fail-fast drop at submit (the spec can never fit this
                // replica's memory budget): unblock the client with an
                // empty completion — zero tokens marks it unserved —
                // instead of hanging its recv forever.
                let _ = done.send(dropped_completion(id));
                requeued.remove(&id);
                continue;
            };
            if !r.is_finished() {
                still.push((id, owner, done));
                continue;
            }
            // Terminal either way below: the once-only re-queue guard
            // entry is dead weight from here on (a long-running server
            // must not accumulate one per rescued request forever).
            requeued.remove(&id);
            let Some(finished_at) = r.finished_at else {
                // Dropped mid-run (context outgrew the budget): the
                // request is terminal but was never served.
                let _ = done.send(dropped_completion(id));
                continue;
            };
            #[cfg(feature = "pjrt")]
            let generated = engine.backend_any().and_then(|any| {
                any.downcast_ref::<crate::engine::pjrt_backend::PjrtBackend>()
                    .and_then(|b| {
                        b.generated_tokens(id).map(|t| t.to_vec())
                    })
            });
            #[cfg(not(feature = "pjrt"))]
            let generated = None;
            let completion = Completion {
                id: id.0,
                latency_us: (finished_at - r.spec.arrival).0,
                ttft_us: r
                    .first_token_at
                    .map(|t| (t - r.spec.arrival).0),
                tokens_decoded: r.spec.total_decode().0,
                generated,
            };
            let _ = done.send(completion);
        }
        watchers = still;

        if shutdown && watchers.is_empty() {
            return;
        }
        if !progressed {
            std::thread::sleep(Duration::from_micros(POLL_TICK.0));
        }
    }
}

/// JSON-lines TCP request format:
/// `{"prompt": "...", "output_tokens": N, "pre_api_tokens": N,
///   "api_ms": N}`
#[derive(Debug, Clone)]
pub struct WireRequest {
    pub prompt: String,
    /// Decode length before the API call (0 = no API call).
    pub pre_api_tokens: u64,
    /// API latency in milliseconds (simulated external service).
    pub api_ms: u64,
    pub output_tokens: u64,
}

impl WireRequest {
    pub fn parse(line: &str) -> anyhow::Result<WireRequest> {
        let v = json::parse(line)?;
        Ok(WireRequest {
            prompt: v.str_field("prompt")?,
            pre_api_tokens: v
                .get("pre_api_tokens")
                .and_then(|x| x.as_u64())
                .unwrap_or(0),
            api_ms: v.get("api_ms").and_then(|x| x.as_u64()).unwrap_or(0),
            output_tokens: v.u64_field("output_tokens")?,
        })
    }

    pub fn to_spec(&self) -> RequestSpec {
        use crate::core::request::{ApiCallSpec, ApiType};
        use crate::core::types::Tokens;
        let prompt_tokens =
            crate::util::tokenizer::valid_len(&self.prompt, 64) as u64;
        let api_calls = if self.pre_api_tokens > 0 {
            vec![ApiCallSpec {
                decode_before: Tokens(self.pre_api_tokens),
                api_type: ApiType::Tool(0),
                duration: Micros(self.api_ms * 1000),
                response_tokens: Tokens(4),
            }]
        } else {
            vec![]
        };
        RequestSpec {
            id: RequestId(0), // assigned by the server
            arrival: Micros::ZERO,
            prompt: self.prompt.clone(),
            prompt_tokens: Tokens(prompt_tokens),
            api_calls,
            final_decode: Tokens(self.output_tokens.max(1)),
        }
    }
}

/// Serve JSON-lines over TCP: one request object per line, one
/// [`Completion`] object per line back. Blocks forever.
pub fn serve_tcp(handle: ServerHandle, addr: &str) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("lamps: serving on {addr}");
    let handle = Arc::new(Mutex::new(handle));
    for stream in listener.incoming() {
        let stream = stream?;
        let handle = {
            let guard = handle.lock().unwrap();
            guard.clone()
        };
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, handle) {
                eprintln!("lamps: connection error: {e}");
            }
        });
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, handle: ServerHandle)
               -> anyhow::Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match WireRequest::parse(&line) {
            Ok(req) => match handle.submit_blocking(req.to_spec()) {
                Ok(completion) => completion.to_json(),
                Err(e) => format!("{{\"error\":\"{e}\"}}"),
            },
            Err(e) => format!("{{\"error\":\"bad request: {e}\"}}"),
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    eprintln!("lamps: {peer} disconnected");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_request_parse_full() {
        let r = WireRequest::parse(
            r#"{"prompt": "hi there", "output_tokens": 12,
                "pre_api_tokens": 4, "api_ms": 50}"#).unwrap();
        assert_eq!(r.output_tokens, 12);
        assert_eq!(r.pre_api_tokens, 4);
        let spec = r.to_spec();
        assert_eq!(spec.api_calls.len(), 1);
        assert_eq!(spec.api_calls[0].duration, Micros(50_000));
        assert_eq!(spec.final_decode.0, 12);
    }

    #[test]
    fn wire_request_defaults() {
        let r = WireRequest::parse(
            r#"{"prompt": "x", "output_tokens": 3}"#).unwrap();
        assert_eq!(r.api_ms, 0);
        assert!(r.to_spec().api_calls.is_empty());
    }

    #[test]
    fn wire_request_rejects_missing_fields() {
        assert!(WireRequest::parse(r#"{"prompt": "x"}"#).is_err());
        assert!(WireRequest::parse("not json").is_err());
    }

    #[test]
    fn completion_json_shape() {
        let c = Completion {
            id: 3,
            latency_us: 1000,
            ttft_us: Some(10),
            tokens_decoded: 5,
            generated: Some(vec![1, 2]),
        };
        let v = json::parse(&c.to_json()).unwrap();
        assert_eq!(v.u64_field("id").unwrap(), 3);
        assert_eq!(v.get("generated").unwrap().as_arr().unwrap().len(), 2);
        let c2 = Completion {
            ttft_us: None,
            generated: None,
            ..c
        };
        let v2 = json::parse(&c2.to_json()).unwrap();
        assert_eq!(v2.get("ttft_us"), Some(&Value::Null));
    }
}
