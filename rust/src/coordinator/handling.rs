//! Memory-waste model for the three handling strategies — INFERCEPT's
//! equations (1)-(3), which LAMPS evaluates with *predicted* values before
//! the request runs (paper §4.2) and the INFERCEPT baseline evaluates with
//! *live* values at API-encounter time:
//!
//! ```text
//! WastePreserve_i = T_INT x C_i x M                                  (1)
//! WasteDiscard_i  = T_fwd(C_i) x C_i x M + T_fwd(C_i) x C_other x M  (2)
//! WasteSwap_i     = 2 x T_swap(C_i) x C_batch x M                    (3)
//! ```
//!
//! `C_i` is request i's context at the API call, `C_other` the context of
//! the co-batched requests, `C_batch = C_i + C_other`. `M` (bytes/token)
//! is a common factor and cancels in the comparison, so waste here is in
//! **token-microseconds**.

use crate::config::CostModel;
use crate::core::request::HandlingStrategy;
use crate::core::types::{Micros, Tokens};

/// Inputs to the waste equations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WasteInputs {
    /// Context size of the request at the API call (tokens), `C_i`.
    pub ctx: Tokens,
    /// API duration `T_INT`.
    pub api_duration: Micros,
    /// Context of other requests in the batch, `C_other`. LAMPS estimates
    /// this by profiling (EMA of observed batch contexts, §3.2.1);
    /// INFERCEPT reads it from the live batch.
    pub c_other: Tokens,
    /// Context tokens expected to be served from the KV prefix cache on
    /// a post-Discard recompute (the full blocks registered at the API
    /// encounter). Shrinks eqn (2)'s forward-pass time: only
    /// `ctx - cached` tokens are actually recomputed. Zero when the
    /// prefix cache is disabled, reproducing the paper's eqn (2)
    /// exactly.
    pub cached: Tokens,
}

impl WasteInputs {
    pub fn c_batch(&self) -> Tokens {
        self.ctx + self.c_other
    }
}

/// Eqn (1): memory idly held for the whole call.
pub fn waste_preserve(inp: &WasteInputs) -> f64 {
    inp.api_duration.0 as f64 * inp.ctx.0 as f64
}

/// Eqn (2): recomputation occupies own context for T_fwd, and stalls the
/// co-batched contexts for the same T_fwd. With prefix caching, the
/// forward pass only covers the uncached tail (`ctx - cached`): cached
/// full blocks are re-pinned, not recomputed, so both the self-occupancy
/// and the co-batch stall shrink proportionally.
pub fn waste_discard(inp: &WasteInputs, cost: &CostModel) -> f64 {
    let recompute = inp.ctx.saturating_sub(inp.cached);
    let t_fwd = cost.prefill_time(recompute).0 as f64;
    t_fwd * inp.ctx.0 as f64 + t_fwd * inp.c_other.0 as f64
}

/// Eqn (3): two transfers (out + in), each stalling the whole batch.
/// With prefix caching, the blocks registered at the swap encounter are
/// expected to still be on-device at the return, so the inbound
/// transfer only moves the uncached tail (`ctx - cached`) — the same
/// optimistic-retention estimate eqn (2) gets — while the outbound
/// transfer still parks everything.
pub fn waste_swap(inp: &WasteInputs, cost: &CostModel) -> f64 {
    let restore = inp.ctx.saturating_sub(inp.cached);
    (cost.swap_time(inp.ctx).0 as f64
        + cost.swap_time(restore).0 as f64)
        * inp.c_batch().0 as f64
}

pub fn waste_of(strategy: HandlingStrategy, inp: &WasteInputs,
                cost: &CostModel) -> f64 {
    match strategy {
        HandlingStrategy::Preserve => waste_preserve(inp),
        HandlingStrategy::Discard => waste_discard(inp, cost),
        HandlingStrategy::Swap => waste_swap(inp, cost),
    }
}

/// Pick the strategy minimizing predicted memory waste. Ties break toward
/// Preserve (cheapest to execute: no transfer, no recompute).
pub fn select_strategy(inp: &WasteInputs, cost: &CostModel)
                       -> HandlingStrategy {
    let mut best = HandlingStrategy::Preserve;
    let mut best_waste = waste_preserve(inp);
    for s in [HandlingStrategy::Discard, HandlingStrategy::Swap] {
        let w = waste_of(s, inp, cost);
        if w < best_waste {
            best = s;
            best_waste = w;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost() -> CostModel {
        // prefill 100 us/tok, swap 30 us/tok
        CostModel::paper_scale()
    }

    #[test]
    fn short_api_preserves() {
        // Math-like: 90 us call, ctx 100 -> preserve waste 9e3, discard
        // waste 1e4*(100+0)... preserve clearly wins.
        let inp = WasteInputs {
            ctx: Tokens(100),
            api_duration: Micros(90),
            c_other: Tokens(0),
            cached: Tokens::ZERO,
        };
        assert_eq!(select_strategy(&inp, &cost()),
                   HandlingStrategy::Preserve);
    }

    #[test]
    fn long_api_small_ctx_discards() {
        // Image-like 20 s call, tiny context, empty batch: recompute is
        // nearly free, preserve wastes 20s x ctx.
        let inp = WasteInputs {
            ctx: Tokens(20),
            api_duration: Micros(20_000_000),
            c_other: Tokens(0),
            cached: Tokens::ZERO,
        };
        assert_eq!(select_strategy(&inp, &cost()),
                   HandlingStrategy::Discard);
    }

    #[test]
    fn long_api_big_ctx_busy_batch_swaps() {
        // Large own context + busy batch: recompute stalls everyone
        // (discard expensive); preserve wastes ctx x 20 s; swap moves
        // 2x1000 tokens.
        let inp = WasteInputs {
            ctx: Tokens(1000),
            api_duration: Micros(20_000_000),
            c_other: Tokens(500),
            cached: Tokens::ZERO,
        };
        let c = cost();
        let wp = waste_preserve(&inp);
        let wd = waste_discard(&inp, &c);
        let ws = waste_swap(&inp, &c);
        assert!(ws < wd && ws < wp,
                "swap {ws} vs discard {wd} vs preserve {wp}");
        assert_eq!(select_strategy(&inp, &c), HandlingStrategy::Swap);
    }

    #[test]
    fn equations_match_formulas() {
        let inp = WasteInputs {
            ctx: Tokens(10),
            api_duration: Micros(1_000),
            c_other: Tokens(5),
            cached: Tokens::ZERO,
        };
        let c = cost();
        assert_eq!(waste_preserve(&inp), 1_000.0 * 10.0);
        // T_fwd(10) = 1000 us; own 1000*10 + other 1000*5
        assert_eq!(waste_discard(&inp, &c), 1000.0 * 10.0 + 1000.0 * 5.0);
        // T_swap(10) = 1000 + 300 us; 2 * 1300 * 15
        assert_eq!(waste_swap(&inp, &c), 2.0 * 1300.0 * 15.0);
    }

    #[test]
    fn zero_duration_ties_to_preserve() {
        let inp = WasteInputs {
            ctx: Tokens(0),
            api_duration: Micros(0),
            c_other: Tokens(0),
            cached: Tokens::ZERO,
        };
        assert_eq!(select_strategy(&inp, &cost()),
                   HandlingStrategy::Preserve);
    }

    #[test]
    fn cached_prefix_discounts_discard_and_swap_restore() {
        // 80 of 100 context tokens sit in cached full blocks: the
        // recompute forward pass covers 20 tokens, not 100, so eqn (2)
        // shrinks 5x; eqn (3)'s inbound transfer likewise covers only
        // the 20-token tail (the outbound still parks all 100); eqn (1)
        // is unchanged.
        let cold = WasteInputs {
            ctx: Tokens(100),
            api_duration: Micros(1_000_000),
            c_other: Tokens(50),
            cached: Tokens::ZERO,
        };
        let warm = WasteInputs {
            cached: Tokens(80),
            ..cold
        };
        let c = cost();
        assert_eq!(waste_discard(&warm, &c),
                   waste_discard(&cold, &c) / 5.0);
        assert_eq!(waste_preserve(&warm), waste_preserve(&cold));
        // T_swap(100) = 4000 us out both ways; in: 4000 cold vs
        // T_swap(20) = 1600 warm; C_batch = 150.
        assert_eq!(waste_swap(&cold, &c), (4000.0 + 4000.0) * 150.0);
        assert_eq!(waste_swap(&warm, &c), (4000.0 + 1600.0) * 150.0);
        // A fully-cached recompute is free — and a fully-resident
        // restore skips even the transfer base; saturation guards
        // cached > ctx (stale estimate after the context shrank).
        let full = WasteInputs {
            cached: Tokens(200),
            ..cold
        };
        assert_eq!(waste_discard(&full, &c), 0.0);
        assert_eq!(waste_swap(&full, &c), 4000.0 * 150.0);
    }

    #[test]
    fn discard_swap_crossover_in_context_size() {
        // Recompute cost grows ~C^2 while swap grows ~(base + 30C) x C:
        // with the calibrated constants the crossover sits at C = 50
        // tokens — "if the pre-API portion is short, Discard is
        // beneficial; otherwise Swap" (paper §2.3).
        let c = cost();
        let long_api = Micros(20_000_000);
        let small = WasteInputs {
            ctx: Tokens(40),
            api_duration: long_api,
            c_other: Tokens(0),
            cached: Tokens::ZERO,
        };
        assert_eq!(select_strategy(&small, &c), HandlingStrategy::Discard);
        let large = WasteInputs {
            ctx: Tokens(100),
            ..small
        };
        assert_eq!(select_strategy(&large, &c), HandlingStrategy::Swap);
    }
}
