//! Token-budgeted batch composer: the compose→execute→commit pipeline.
//!
//! Every scheduling round the engine no longer "materializes then
//! decodes" serially; it runs three phases:
//!
//! 1. **compose** (this module, pure): from the admitted running set,
//!    assemble one mixed iteration under the
//!    [`ComposeConfig`](crate::config::ComposeConfig) token budget —
//!    which requests decode one token, and which materialize a *chunk*
//!    of pending prefill/recompute work. Long prompts and
//!    discard-recomputes are split into `prefill_chunk`-sized segments,
//!    so a 4k-token recompute charges at most one chunk's forward time
//!    to each co-batched decode iteration instead of stalling everyone
//!    for the whole pass (the waste INFERCEPT's eqn (2) charges).
//! 2. **execute** (engine): run the planned chunks and the decode batch
//!    on the [`Backend`](crate::engine::backend::Backend), measuring (or
//!    simulating) elapsed time. Synchronous swap restores execute here
//!    too; asynchronous ones run in the
//!    [`TransferQueue`](crate::kv::TransferQueue) instead and never
//!    appear in a plan.
//! 3. **commit** (engine): apply the results — advance materialization
//!    cursors, append decoded tokens, route API encounters and
//!    completions, update the profiling EMAs.
//!
//! The split keeps composition a pure function of request state, which
//! is what makes it testable in isolation and reusable across both
//! backends and every scheduler policy; it is also the seam the
//! ROADMAP's multi-replica dispatch will plug into (compose per replica,
//! execute in parallel).
//!
//! **Budget semantics.** A decode slot costs 1 token (it appends one);
//! a prefill chunk costs its length. Decode-ready requests are always
//! scheduled — the budget throttles prefill, never decode — and at
//! least one prefill chunk makes progress per round even under an
//! exhausted budget, so composition can never livelock.

use crate::config::ComposeConfig;
use crate::core::types::{RequestId, Tokens};
use crate::engine::backend::DecodeSlot;

/// Composer's view of one admitted (running) request.
#[derive(Debug, Clone, Copy)]
pub struct ComposeItem {
    pub id: RequestId,
    /// Prefill / recompute tokens still owed before decode can resume.
    /// Already net of KV prefix-cache hits: the engine discounts cached
    /// leading tokens at admission (`Engine::allocate_admitted`), so
    /// chunking starts at the first *uncached* token and a fully-cached
    /// prefix composes as `pending == 0` — straight into the decode
    /// batch with no prefill chunk at all.
    pub pending: Tokens,
    /// Full logical context (the decode slot's ctx once materialized).
    pub logical_context: Tokens,
    /// The request's context is parked in swap space and must be
    /// restored synchronously before its chunk runs (sync-swap mode
    /// only; async restores go through the `TransferQueue` and are never
    /// offered to the composer).
    pub needs_swap_in: bool,
}

/// One planned materialization step for a request.
#[derive(Debug, Clone, Copy)]
pub struct PrefillChunk {
    pub id: RequestId,
    /// Tokens to materialize this iteration (may be zero for a pure
    /// swap-in restore whose API response was empty).
    pub tokens: Tokens,
    /// Restore the swapped context before materializing (sync mode).
    pub swap_in: bool,
    /// This chunk completes the request's materialization; the request
    /// joins the decode batch in the same iteration (matching the
    /// legacy prefill-then-decode round exactly when chunking is off).
    pub finishes: bool,
}

/// The composed iteration: what execute() runs and commit() applies.
#[derive(Debug, Clone, Default)]
pub struct IterationPlan {
    pub prefill: Vec<PrefillChunk>,
    pub decode: Vec<DecodeSlot>,
    /// Tokens of budget consumed (decode slots + chunk lengths).
    pub budget_used: u64,
}

impl IterationPlan {
    pub fn is_empty(&self) -> bool {
        self.prefill.is_empty() && self.decode.is_empty()
    }
}

/// Assemble one iteration from the running set (given in priority
/// order). Pure: no engine state is touched.
pub fn compose(cfg: &ComposeConfig, items: &[ComposeItem])
               -> IterationPlan {
    let mut plan = IterationPlan::default();
    let budget = cfg.max_batch_tokens.unwrap_or(u64::MAX);

    // Decode-ready requests first: each costs one budget token but is
    // never dropped from the iteration (decode latency is the metric
    // chunking protects).
    for item in items {
        if item.pending == Tokens::ZERO && !item.needs_swap_in {
            plan.decode.push(DecodeSlot {
                id: item.id,
                ctx: item.logical_context,
            });
            plan.budget_used += 1;
        }
    }

    // Prefill chunks from the leftover budget, in priority order.
    for item in items {
        if item.pending == Tokens::ZERO && !item.needs_swap_in {
            continue;
        }
        let left = budget.saturating_sub(plan.budget_used);
        let cap = cfg
            .prefill_chunk
            .unwrap_or(u64::MAX)
            .min(if cfg.max_batch_tokens.is_some() {
                left
            } else {
                u64::MAX
            });
        let progress_starved = plan.prefill.is_empty() && cap == 0;
        let chunk = if progress_starved {
            // Liveness floor: the head-of-line materialization always
            // advances by one chunk per round, budget notwithstanding.
            item.pending.0.min(cfg.prefill_chunk.unwrap_or(u64::MAX))
        } else {
            item.pending.0.min(cap)
        };
        if chunk == 0 && item.pending > Tokens::ZERO && !item.needs_swap_in
        {
            continue; // budget-starved this round; retried next round
        }
        let finishes = chunk == item.pending.0;
        plan.prefill.push(PrefillChunk {
            id: item.id,
            tokens: Tokens(chunk),
            swap_in: item.needs_swap_in,
            finishes,
        });
        plan.budget_used += chunk;
        if finishes {
            plan.decode.push(DecodeSlot {
                id: item.id,
                ctx: item.logical_context,
            });
            plan.budget_used += 1;
        }
    }

    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(id: u64, pending: u64, ctx: u64) -> ComposeItem {
        ComposeItem {
            id: RequestId(id),
            pending: Tokens(pending),
            logical_context: Tokens(ctx),
            needs_swap_in: false,
        }
    }

    fn legacy() -> ComposeConfig {
        ComposeConfig::default()
    }

    fn chunked(chunk: u64) -> ComposeConfig {
        ComposeConfig {
            prefill_chunk: Some(chunk),
            ..ComposeConfig::default()
        }
    }

    #[test]
    fn legacy_mode_materializes_whole_and_decodes_same_round() {
        let plan = compose(&legacy(), &[item(1, 0, 10), item(2, 40, 40)]);
        assert_eq!(plan.decode.len(), 2, "finisher joins decode");
        assert_eq!(plan.prefill.len(), 1);
        assert_eq!(plan.prefill[0].tokens, Tokens(40));
        assert!(plan.prefill[0].finishes);
    }

    #[test]
    fn long_prefill_is_chunked() {
        let cfg = chunked(16);
        let plan = compose(&cfg, &[item(1, 0, 10), item(2, 40, 40)]);
        assert_eq!(plan.prefill.len(), 1);
        assert_eq!(plan.prefill[0].tokens, Tokens(16));
        assert!(!plan.prefill[0].finishes);
        // The partial request does not decode yet; the ready one does.
        assert_eq!(plan.decode.len(), 1);
        assert_eq!(plan.decode[0].id, RequestId(1));
    }

    #[test]
    fn final_chunk_joins_decode() {
        let cfg = chunked(16);
        let plan = compose(&cfg, &[item(2, 12, 40)]);
        assert_eq!(plan.prefill[0].tokens, Tokens(12));
        assert!(plan.prefill[0].finishes);
        assert_eq!(plan.decode.len(), 1);
        assert_eq!(plan.decode[0].ctx, Tokens(40));
    }

    #[test]
    fn token_budget_throttles_prefill_not_decode() {
        let cfg = ComposeConfig {
            max_batch_tokens: Some(20),
            prefill_chunk: Some(64),
            ..ComposeConfig::default()
        };
        let items = [item(1, 0, 5), item(2, 0, 5), item(3, 100, 100),
                     item(4, 100, 100)];
        let plan = compose(&cfg, &items);
        // Both decodes run (2 tokens), first prefiller gets the
        // remaining 18, the second is starved to next round.
        assert_eq!(plan.decode.len(), 2);
        assert_eq!(plan.prefill.len(), 1);
        assert_eq!(plan.prefill[0].id, RequestId(3));
        assert_eq!(plan.prefill[0].tokens, Tokens(18));
        assert_eq!(plan.budget_used, 20);
    }

    #[test]
    fn exhausted_budget_still_makes_progress() {
        // Budget smaller than the decode batch: decodes all run anyway,
        // and the head-of-line prefiller still advances (liveness).
        let cfg = ComposeConfig {
            max_batch_tokens: Some(1),
            prefill_chunk: Some(8),
            ..ComposeConfig::default()
        };
        let items = [item(1, 0, 5), item(2, 0, 5), item(3, 30, 30)];
        let plan = compose(&cfg, &items);
        assert_eq!(plan.decode.len(), 2);
        assert_eq!(plan.prefill.len(), 1);
        assert!(plan.prefill[0].tokens >= Tokens(1));
        assert!(plan.prefill[0].tokens <= Tokens(8));
    }

    #[test]
    fn pure_swap_restore_composes_with_zero_tokens() {
        // Swap return with an empty API response: nothing to prefill,
        // but the restore must still be planned and decode follows.
        let mut it = item(1, 0, 25);
        it.needs_swap_in = true;
        let plan = compose(&legacy(), &[it]);
        assert_eq!(plan.prefill.len(), 1);
        assert_eq!(plan.prefill[0].tokens, Tokens::ZERO);
        assert!(plan.prefill[0].swap_in);
        assert!(plan.prefill[0].finishes);
        assert_eq!(plan.decode.len(), 1);
    }

    #[test]
    fn priority_order_is_preserved() {
        let cfg = chunked(10);
        let items = [item(9, 50, 50), item(3, 50, 50), item(7, 0, 4)];
        let plan = compose(&cfg, &items);
        // Prefill chunks follow the given (priority) order.
        assert_eq!(plan.prefill[0].id, RequestId(9));
        assert_eq!(plan.prefill[1].id, RequestId(3));
        assert_eq!(plan.decode[0].id, RequestId(7));
    }

    #[test]
    fn empty_input_is_empty_plan() {
        let plan = compose(&legacy(), &[]);
        assert!(plan.is_empty());
        assert_eq!(plan.budget_used, 0);
    }
}
