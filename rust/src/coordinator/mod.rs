//! The paper's L3 contribution: memory-waste-minimizing handling strategy
//! selection (INFERCEPT equations (1)-(3)), the memory-over-time ranking
//! function, and the scheduling policies (FCFS / SJF / SJF-total / LAMPS).

pub mod batch;
pub mod handling;
pub mod ranking;
pub mod scheduler;

pub use batch::{compose, ComposeItem, IterationPlan, PrefillChunk};
pub use handling::{select_strategy, WasteInputs};
pub use scheduler::{ScheduleContext, Scheduler, Score};
