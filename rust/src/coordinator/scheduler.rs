//! Scheduling policies: the score function each policy assigns to a
//! waiting request. The engine sorts eligible requests by
//! `(starving desc, score asc, id asc)` each iteration (Algorithm 1 line
//! 16 + the §4.4 starvation promotion).

use crate::config::{CostModel, SchedulerKind};
use crate::coordinator::ranking::{memory_over_time, RankInputs};
use crate::core::request::Request;
use crate::core::types::{Micros, Tokens};

/// Live engine state the score functions may consult.
#[derive(Debug, Clone, Copy)]
pub struct ScheduleContext {
    pub cost: CostModel,
    /// Estimate of one decode iteration's duration (EMA of observed).
    pub t_iter_est: Micros,
    /// Profiled co-batched context estimate (`C_other`).
    pub c_other_est: Tokens,
    pub iteration: u64,
}

impl ScheduleContext {
    pub fn rank_inputs(&self) -> RankInputs {
        RankInputs {
            t_iter: self.t_iter_est,
            c_other_est: self.c_other_est,
        }
    }
}

/// A scheduling policy: maps a request to a sortable score (lower runs
/// first).
pub trait Scheduler {
    fn kind(&self) -> SchedulerKind;
    fn score(&self, r: &Request, ctx: &ScheduleContext) -> f64;

    /// Whether scores depend on live engine state and therefore benefit
    /// from the selective-update cache (§4.3). Static policies (FCFS/SJF)
    /// never need recomputation.
    fn is_dynamic(&self) -> bool {
        false
    }
}

/// First-come first-served (vLLM / INFERCEPT default): queue-entry time,
/// then request id (the paper's Fig 3 breaks the simultaneous-arrival tie
/// by request ID). `queue_key` is bumped to the API-return time when a
/// request re-enters the queue — vLLM treats the post-API continuation as
/// a new job (paper §1), which is what "prioritize new requests over
/// ongoing ones" (§6.2) means for the ToolBench throughput trade-off.
#[derive(Debug, Default)]
pub struct Fcfs;

impl Scheduler for Fcfs {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Fcfs
    }

    fn score(&self, r: &Request, _ctx: &ScheduleContext) -> f64 {
        r.queue_key.0 as f64 * 1e9 + r.spec.id.0 as f64
    }
}

/// Remaining predicted decode work in token units: outstanding decode
/// tokens across segments plus pending recompute/prefill work. The
/// paper's size policies are remaining-work (SRPT-style): in Fig 3b, R2's
/// post-API part is "length 2 (including recomputation)" and R1 "has two
/// units remaining, so R2 must wait" — a tie on remaining work resolved
/// toward the earlier request.
fn remaining_work_tokens(r: &Request) -> f64 {
    let mut remaining = r.pending_materialize.0 as f64;
    for seg in r.segment..r.spec.num_segments() {
        let done = if seg == r.segment {
            r.segment_generated.0
        } else {
            0
        };
        remaining +=
            r.predictions[seg].decode_tokens.0.saturating_sub(done) as f64;
    }
    remaining
}

/// Shortest Job First by *predicted output length only* (Fig 3b):
/// remaining decode work, API time ignored.
#[derive(Debug, Default)]
pub struct Sjf;

impl Scheduler for Sjf {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Sjf
    }

    fn score(&self, r: &Request, _ctx: &ScheduleContext) -> f64 {
        remaining_work_tokens(r)
    }

    fn is_dynamic(&self) -> bool {
        true // remaining work shrinks as the request progresses
    }
}

/// SJF by *total length* (Fig 3c): remaining decode work plus remaining
/// API durations converted to token-generation units.
#[derive(Debug, Default)]
pub struct SjfTotal;

impl Scheduler for SjfTotal {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::SjfTotal
    }

    fn score(&self, r: &Request, ctx: &ScheduleContext) -> f64 {
        let t_iter = ctx.t_iter_est.0.max(1) as f64;
        let api_units: f64 = (r.segment..r.spec.num_segments())
            .map(|seg| {
                r.predictions[seg]
                    .api_duration
                    .map_or(0.0, |d| d.0 as f64 / t_iter)
            })
            .sum();
        remaining_work_tokens(r) + api_units
    }

    fn is_dynamic(&self) -> bool {
        true
    }
}

/// LAMPS: rank by the remaining memory-over-time integral (§4.3).
#[derive(Debug, Default)]
pub struct Lamps;

impl Scheduler for Lamps {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Lamps
    }

    fn score(&self, r: &Request, ctx: &ScheduleContext) -> f64 {
        memory_over_time(r, &ctx.cost, &ctx.rank_inputs())
    }

    fn is_dynamic(&self) -> bool {
        true
    }
}

/// Factory from the config enum.
pub fn make_scheduler(kind: SchedulerKind) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Fcfs => Box::new(Fcfs),
        SchedulerKind::Sjf => Box::new(Sjf),
        SchedulerKind::SjfTotal => Box::new(SjfTotal),
        SchedulerKind::Lamps => Box::new(Lamps),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::{ApiCallSpec, ApiType, HandlingStrategy,
                               RequestSpec, SegmentPrediction};
    use crate::core::types::RequestId;

    fn ctx() -> ScheduleContext {
        ScheduleContext {
            cost: CostModel::unit(),
            t_iter_est: Micros(1_000_000),
            c_other_est: Tokens(3),
            iteration: 0,
        }
    }

    fn req(id: u64, arrival: u64, pre: u64, api_units: u64, post: u64)
           -> Request {
        let spec = RequestSpec {
            id: RequestId(id),
            arrival: Micros(arrival),
            prompt: String::new(),
            prompt_tokens: Tokens(0),
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(pre),
                api_type: ApiType::Qa,
                duration: Micros(api_units * 1_000_000),
                response_tokens: Tokens(0),
            }],
            final_decode: Tokens(post),
        };
        let preds = vec![
            SegmentPrediction {
                decode_tokens: Tokens(pre),
                api_duration: Some(Micros(api_units * 1_000_000)),
                response_tokens: Tokens(0),
            },
            SegmentPrediction {
                decode_tokens: Tokens(post),
                api_duration: None,
                response_tokens: Tokens(0),
            },
        ];
        Request::new(spec, preds, vec![HandlingStrategy::Preserve])
    }

    #[test]
    fn fcfs_orders_by_arrival_then_id() {
        let s = Fcfs;
        let c = ctx();
        let a = req(5, 100, 1, 1, 1);
        let b = req(2, 200, 1, 1, 1);
        assert!(s.score(&a, &c) < s.score(&b, &c));
        let same_arrival_low_id = req(1, 100, 9, 9, 9);
        assert!(s.score(&same_arrival_low_id, &c) < s.score(&a, &c));
    }

    #[test]
    fn sjf_ignores_api_time() {
        let s = Sjf;
        let c = ctx();
        // Fig 3: SJF orders R2 (len 2) < R3 (3) < R1 (6) despite R2's long
        // API.
        let r1 = req(1, 0, 5, 2, 1);
        let r2 = req(2, 0, 1, 7, 1);
        let r3 = req(3, 0, 2, 1, 1);
        assert!(s.score(&r2, &c) < s.score(&r3, &c));
        assert!(s.score(&r3, &c) < s.score(&r1, &c));
    }

    #[test]
    fn sjf_total_includes_api_time() {
        let s = SjfTotal;
        let c = ctx();
        // Fig 3c: totals R1 = 8, R2 = 9, R3 = 4 -> R3 < R1 < R2.
        let r1 = req(1, 0, 5, 2, 1);
        let r2 = req(2, 0, 1, 7, 1);
        let r3 = req(3, 0, 2, 1, 1);
        assert_eq!(s.score(&r1, &c), 8.0);
        assert_eq!(s.score(&r2, &c), 9.0);
        assert_eq!(s.score(&r3, &c), 4.0);
    }

    #[test]
    fn factory_kinds() {
        for kind in [SchedulerKind::Fcfs, SchedulerKind::Sjf,
                     SchedulerKind::SjfTotal, SchedulerKind::Lamps] {
            assert_eq!(make_scheduler(kind).kind(), kind);
        }
    }

    #[test]
    fn dynamic_flags() {
        // All size-based policies track remaining work; only FCFS is
        // static.
        assert!(Lamps.is_dynamic());
        assert!(Sjf.is_dynamic());
        assert!(SjfTotal.is_dynamic());
        assert!(!Fcfs.is_dynamic());
    }

    #[test]
    fn sjf_score_shrinks_with_progress() {
        let c = ctx();
        let mut r = req(1, 0, 5, 2, 3);
        let before = Sjf.score(&r, &c);
        assert_eq!(before, 8.0);
        r.segment_generated = Tokens(4);
        assert_eq!(Sjf.score(&r, &c), 4.0);
        // pending recompute counts as remaining work
        r.pending_materialize = Tokens(3);
        assert_eq!(Sjf.score(&r, &c), 7.0);
    }
}
