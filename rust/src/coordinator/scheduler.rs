//! Scheduling policies: the score function each policy assigns to a
//! waiting request. The engine sorts eligible requests by
//! `(starving desc, score asc, id asc)` each iteration (Algorithm 1 line
//! 16 + the §4.4 starvation promotion).

use std::cmp::Ordering;

use crate::config::{CostModel, SchedulerKind};
use crate::coordinator::ranking::{memory_over_time, RankInputs};
use crate::core::request::Request;
use crate::core::types::{Micros, Tokens};

/// Composite, totally-ordered scheduling key: an f64 primary value plus
/// an integer tie-breaker compared exactly.
///
/// Folding a tie-breaker into the f64 itself (the old
/// `queue_key * 1e9 + id`) collides once the primary exceeds ~2^53/1e9:
/// the mantissa runs out and distinct (key, id) pairs map to the same —
/// or worse, *reordered* — floats. Keeping the tie-breaker as an integer
/// field makes the key exact for any u64 id, and the primary alone stays
/// exact up to 2^53 (as microseconds: ~285 years of uptime).
#[derive(Debug, Clone, Copy)]
pub struct Score {
    /// Policy value; lower runs first.
    pub primary: f64,
    /// Exact integer tie-breaker (0 for policies that don't need one —
    /// the engine's final same-score fallback is the request id).
    pub tie: u64,
}

impl Score {
    pub const MAX: Score = Score {
        primary: f64::INFINITY,
        tie: u64::MAX,
    };

    pub fn of(primary: f64) -> Score {
        Score { primary, tie: 0 }
    }

    pub fn with_tie(primary: f64, tie: u64) -> Score {
        Score { primary, tie }
    }
}

impl PartialEq for Score {
    fn eq(&self, other: &Score) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Score {}

impl PartialOrd for Score {
    fn partial_cmp(&self, other: &Score) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Score {
    fn cmp(&self, other: &Score) -> Ordering {
        self.primary
            .total_cmp(&other.primary)
            .then(self.tie.cmp(&other.tie))
    }
}

/// Convenience for tests and assertions against plain policy values.
impl PartialEq<f64> for Score {
    fn eq(&self, other: &f64) -> bool {
        self.primary == *other
    }
}

/// Live engine state the score functions may consult.
#[derive(Debug, Clone, Copy)]
pub struct ScheduleContext {
    pub cost: CostModel,
    /// Estimate of one decode iteration's duration (EMA of observed).
    pub t_iter_est: Micros,
    /// Profiled co-batched context estimate (`C_other`).
    pub c_other_est: Tokens,
    pub iteration: u64,
    /// Chunked prefill is enabled: scores must charge for the held
    /// context of partially-materialized requests (a state that only
    /// exists when materialization can pause mid-way).
    pub account_prefill: bool,
    /// Block size of an active KV prefix cache (`None` = caching off):
    /// discounts the rank integral's discard term by the expected cached
    /// prefix (see [`RankInputs::prefix_cached_block`]).
    pub prefix_cached_block: Option<u64>,
}

impl ScheduleContext {
    pub fn rank_inputs(&self) -> RankInputs {
        RankInputs {
            t_iter: self.t_iter_est,
            c_other_est: self.c_other_est,
            account_prefill: self.account_prefill,
            prefix_cached_block: self.prefix_cached_block,
        }
    }
}

/// A scheduling policy: maps a request to a sortable score (lower runs
/// first).
pub trait Scheduler {
    fn kind(&self) -> SchedulerKind;
    fn score(&self, r: &Request, ctx: &ScheduleContext) -> Score;

    /// Whether scores depend on live engine state and therefore benefit
    /// from the selective-update cache (§4.3). Static policies (FCFS/SJF)
    /// never need recomputation.
    fn is_dynamic(&self) -> bool {
        false
    }
}

/// First-come first-served (vLLM / INFERCEPT default): queue-entry time,
/// then request id (the paper's Fig 3 breaks the simultaneous-arrival tie
/// by request ID). `queue_key` is bumped to the API-return time when a
/// request re-enters the queue — vLLM treats the post-API continuation as
/// a new job (paper §1), which is what "prioritize new requests over
/// ongoing ones" (§6.2) means for the ToolBench throughput trade-off.
#[derive(Debug, Default)]
pub struct Fcfs;

impl Scheduler for Fcfs {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Fcfs
    }

    fn score(&self, r: &Request, _ctx: &ScheduleContext) -> Score {
        // queue_key microseconds stay exact in the f64 primary up to
        // 2^53 us; the id is an exact integer tie instead of being
        // folded into the mantissa.
        Score::with_tie(r.queue_key.0 as f64, r.spec.id.0)
    }
}

/// Remaining predicted decode work in token units: outstanding decode
/// tokens across segments plus pending recompute/prefill work. The
/// paper's size policies are remaining-work (SRPT-style): in Fig 3b, R2's
/// post-API part is "length 2 (including recomputation)" and R1 "has two
/// units remaining, so R2 must wait" — a tie on remaining work resolved
/// toward the earlier request.
fn remaining_work_tokens(r: &Request) -> f64 {
    let mut remaining = r.pending_materialize.0 as f64;
    for seg in r.segment..r.spec.num_segments() {
        let done = if seg == r.segment {
            r.segment_generated.0
        } else {
            0
        };
        remaining +=
            r.predictions[seg].decode_tokens.0.saturating_sub(done) as f64;
    }
    remaining
}

/// Shortest Job First by *predicted output length only* (Fig 3b):
/// remaining decode work, API time ignored.
#[derive(Debug, Default)]
pub struct Sjf;

impl Scheduler for Sjf {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Sjf
    }

    fn score(&self, r: &Request, _ctx: &ScheduleContext) -> Score {
        Score::of(remaining_work_tokens(r))
    }

    fn is_dynamic(&self) -> bool {
        true // remaining work shrinks as the request progresses
    }
}

/// SJF by *total length* (Fig 3c): remaining decode work plus remaining
/// API durations converted to token-generation units.
#[derive(Debug, Default)]
pub struct SjfTotal;

impl Scheduler for SjfTotal {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::SjfTotal
    }

    fn score(&self, r: &Request, ctx: &ScheduleContext) -> Score {
        let t_iter = ctx.t_iter_est.0.max(1) as f64;
        let api_units: f64 = (r.segment..r.spec.num_segments())
            .map(|seg| {
                r.predictions[seg]
                    .api_duration
                    .map_or(0.0, |d| d.0 as f64 / t_iter)
            })
            .sum();
        Score::of(remaining_work_tokens(r) + api_units)
    }

    fn is_dynamic(&self) -> bool {
        true
    }
}

/// LAMPS: rank by the remaining memory-over-time integral (§4.3).
#[derive(Debug, Default)]
pub struct Lamps;

impl Scheduler for Lamps {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Lamps
    }

    fn score(&self, r: &Request, ctx: &ScheduleContext) -> Score {
        Score::of(memory_over_time(r, &ctx.cost, &ctx.rank_inputs()))
    }

    fn is_dynamic(&self) -> bool {
        true
    }
}

/// Factory from the config enum.
pub fn make_scheduler(kind: SchedulerKind) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Fcfs => Box::new(Fcfs),
        SchedulerKind::Sjf => Box::new(Sjf),
        SchedulerKind::SjfTotal => Box::new(SjfTotal),
        SchedulerKind::Lamps => Box::new(Lamps),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::{ApiCallSpec, ApiType, HandlingStrategy,
                               RequestSpec, SegmentPrediction};
    use crate::core::types::RequestId;

    fn ctx() -> ScheduleContext {
        ScheduleContext {
            cost: CostModel::unit(),
            t_iter_est: Micros(1_000_000),
            c_other_est: Tokens(3),
            iteration: 0,
            account_prefill: false,
            prefix_cached_block: None,
        }
    }

    fn req(id: u64, arrival: u64, pre: u64, api_units: u64, post: u64)
           -> Request {
        let spec = RequestSpec {
            id: RequestId(id),
            arrival: Micros(arrival),
            prompt: String::new(),
            prompt_tokens: Tokens(0),
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(pre),
                api_type: ApiType::Qa,
                duration: Micros(api_units * 1_000_000),
                response_tokens: Tokens(0),
            }],
            final_decode: Tokens(post),
        };
        let preds = vec![
            SegmentPrediction {
                decode_tokens: Tokens(pre),
                api_duration: Some(Micros(api_units * 1_000_000)),
                response_tokens: Tokens(0),
            },
            SegmentPrediction {
                decode_tokens: Tokens(post),
                api_duration: None,
                response_tokens: Tokens(0),
            },
        ];
        Request::new(spec, preds, vec![HandlingStrategy::Preserve])
    }

    #[test]
    fn fcfs_orders_by_arrival_then_id() {
        let s = Fcfs;
        let c = ctx();
        let a = req(5, 100, 1, 1, 1);
        let b = req(2, 200, 1, 1, 1);
        assert!(s.score(&a, &c) < s.score(&b, &c));
        let same_arrival_low_id = req(1, 100, 9, 9, 9);
        assert!(s.score(&same_arrival_low_id, &c) < s.score(&a, &c));
    }

    #[test]
    fn fcfs_key_is_integer_safe_at_large_uptimes() {
        // Regression: the old f64 key `queue_key * 1e9 + id` exhausted
        // the mantissa once queue_key exceeded 2^53/1e9 us (~9 virtual
        // seconds!) and collided/reordered ids. The composite key ties
        // by id exactly and still separates adjacent microseconds.
        let s = Fcfs;
        let c = ctx();
        let big = 1u64 << 40; // ~13 days of uptime in microseconds
        let mut a = req(1, 0, 1, 1, 1);
        a.queue_key = Micros(big);
        let mut b = req(2, 0, 1, 1, 1);
        b.queue_key = Micros(big);
        assert!(s.score(&a, &c) < s.score(&b, &c),
                "equal keys must tie-break by id");
        let mut later = req(0, 0, 1, 1, 1);
        later.queue_key = Micros(big + 1);
        assert!(s.score(&b, &c) < s.score(&later, &c),
                "1 us later must rank later regardless of id");
    }

    #[test]
    fn score_total_order() {
        assert!(Score::of(1.0) < Score::of(2.0));
        assert!(Score::with_tie(1.0, 0) < Score::with_tie(1.0, 1));
        assert_eq!(Score::with_tie(3.0, 7), Score::with_tie(3.0, 7));
        assert!(Score::of(5.0) < Score::MAX);
        assert_eq!(Score::of(4.5), 4.5);
    }

    #[test]
    fn sjf_ignores_api_time() {
        let s = Sjf;
        let c = ctx();
        // Fig 3: SJF orders R2 (len 2) < R3 (3) < R1 (6) despite R2's long
        // API.
        let r1 = req(1, 0, 5, 2, 1);
        let r2 = req(2, 0, 1, 7, 1);
        let r3 = req(3, 0, 2, 1, 1);
        assert!(s.score(&r2, &c) < s.score(&r3, &c));
        assert!(s.score(&r3, &c) < s.score(&r1, &c));
    }

    #[test]
    fn sjf_total_includes_api_time() {
        let s = SjfTotal;
        let c = ctx();
        // Fig 3c: totals R1 = 8, R2 = 9, R3 = 4 -> R3 < R1 < R2.
        let r1 = req(1, 0, 5, 2, 1);
        let r2 = req(2, 0, 1, 7, 1);
        let r3 = req(3, 0, 2, 1, 1);
        assert_eq!(s.score(&r1, &c), 8.0);
        assert_eq!(s.score(&r2, &c), 9.0);
        assert_eq!(s.score(&r3, &c), 4.0);
    }

    #[test]
    fn factory_kinds() {
        for kind in [SchedulerKind::Fcfs, SchedulerKind::Sjf,
                     SchedulerKind::SjfTotal, SchedulerKind::Lamps] {
            assert_eq!(make_scheduler(kind).kind(), kind);
        }
    }

    #[test]
    fn dynamic_flags() {
        // All size-based policies track remaining work; only FCFS is
        // static.
        assert!(Lamps.is_dynamic());
        assert!(Sjf.is_dynamic());
        assert!(SjfTotal.is_dynamic());
        assert!(!Fcfs.is_dynamic());
    }

    #[test]
    fn sjf_score_shrinks_with_progress() {
        let c = ctx();
        let mut r = req(1, 0, 5, 2, 3);
        let before = Sjf.score(&r, &c);
        assert_eq!(before, 8.0);
        r.segment_generated = Tokens(4);
        assert_eq!(Sjf.score(&r, &c), 4.0);
        // pending recompute counts as remaining work
        r.pending_materialize = Tokens(3);
        assert_eq!(Sjf.score(&r, &c), 7.0);
    }
}
