//! LAMPS's rank function: the **memory-over-time integral** (paper §4.3,
//! Fig 4) of a request's remaining predicted lifetime, including the waste
//! terms of its assigned API handling strategies.
//!
//! > "Our insight is that evaluating memory usage by integrating the
//! > memory-over-time function offers a more accurate measure of resource
//! > consumption than relying on instantaneous memory values." (§4.2)
//!
//! Units: token-microseconds. Decode phases contribute a ramp
//! `sum_{k=1..d} (ctx + k) * t_iter`; each API call contributes its waste
//! equation value (eqns (1)-(3), `handling.rs`) for the strategy assigned
//! to it. Lower integral -> scheduled earlier.

use crate::config::CostModel;
use crate::coordinator::handling::{waste_of, WasteInputs};
use crate::core::request::{HandlingStrategy, Request, RequestSpec,
                           SegmentPrediction};
use crate::core::types::{Micros, Tokens};

/// Live quantities the score depends on (profiled by the engine).
///
/// Epoch-cache contract (PR 8): every field here, and every term the
/// rank integrals below sum, is a pure function of engine state — no
/// wall clock, no RNG, no iteration-order dependence. That is what
/// makes `Engine`'s epoch-keyed memo of `load_memory_over_time` sound:
/// within one `load_epoch` (no state mutation since the last
/// `touch_load`) a recompute is bitwise-identical to the memoized
/// value. Anything added here that breaks that purity must invalidate
/// the cache on change, or cached placement silently diverges from the
/// stateless oracle (debug/audited builds shadow-recompute and abort
/// on the first divergence).
#[derive(Debug, Clone, Copy)]
pub struct RankInputs {
    /// Current estimate of one decode iteration's duration.
    pub t_iter: Micros,
    /// Profiled average co-batched context, the `C_other` estimate
    /// (§3.2.1 "This estimation involves profiling the number of requests
    /// in a batch").
    pub c_other_est: Tokens,
    /// Chunked prefill enabled: charge the held context of
    /// partially-materialized requests for their remaining prefill time.
    /// Off (the legacy engine), that state never exists and the integral
    /// is bit-identical to the original formula.
    pub account_prefill: bool,
    /// Block size of an *active* KV prefix cache, `None` when caching is
    /// off. When set, the discard waste term is discounted by the
    /// expected cached prefix — the full blocks of the context at the
    /// API call, which the engine registers at the encounter and the
    /// recompute re-pins instead of recomputing (the same optimistic
    /// retention estimate `Engine::cached_recompute_estimate` feeds the
    /// handling-strategy choice). `None` keeps every score byte-identical
    /// to the uncached engine.
    pub prefix_cached_block: Option<u64>,
}

/// Memory-over-time integral of the *remaining* predicted lifetime of `r`.
pub fn memory_over_time(r: &Request, cost: &CostModel,
                        inputs: &RankInputs) -> f64 {
    let mut total = 0.0;

    // Chunked prefill can pause a request mid-materialization (context
    // partially live, `pending_materialize` still owed). The live part
    // sits in device memory for the remaining prefill time before the
    // decode ramp below even starts — charge it, or half-prefilled
    // giants rank as if their held KV were free.
    if inputs.account_prefill
        && r.pending_materialize > Tokens::ZERO
        && r.context > Tokens::ZERO
    {
        let t_mat = cost.prefill_time(r.pending_materialize).0 as f64;
        total += t_mat * r.context.0 as f64;
    }

    total + segments_integral(r.segment, r.segment_generated.0,
                              r.logical_context.0 as f64,
                              r.spec.num_segments(), &r.predictions,
                              &r.handling, cost, inputs)
}

/// Integral for a *not-yet-started* request, scored straight from its
/// spec — what the memory-over-time placement policy uses to weigh
/// enqueued-but-unsubmitted arrivals without materializing a throwaway
/// [`Request`] (and its prompt `String` clone) per probe. Exactly
/// equals `memory_over_time` of a freshly constructed request.
pub fn memory_over_time_fresh(spec: &RequestSpec,
                              predictions: &[SegmentPrediction],
                              handling: &[HandlingStrategy],
                              cost: &CostModel,
                              inputs: &RankInputs) -> f64 {
    segments_integral(0, 0, spec.prompt_tokens.0 as f64,
                      spec.num_segments(), predictions, handling, cost,
                      inputs)
}

/// Placement-probe variant of [`memory_over_time_fresh`] that also
/// charges the arrival's **prefill leg**: the materialized context sits
/// in device memory for the remaining prefill time before the decode
/// ramp even starts, and with `cached` leading tokens already resident
/// in the target replica's prefix cache only the remainder must be
/// materialized. Prefix-affinity placement discounts exactly this leg,
/// so the rank integral itself — not a bolted-on heuristic — steers
/// shared-prefix arrivals toward the replica that holds their prefix.
///
/// With `cached = 0` the leg is the full prompt's; it is then the same
/// on every replica and cancels out of any cross-replica comparison,
/// which is why the plain memory-over-time placement never needed it.
pub fn memory_over_time_fresh_prefixed(spec: &RequestSpec,
                                       predictions: &[SegmentPrediction],
                                       handling: &[HandlingStrategy],
                                       cost: &CostModel,
                                       inputs: &RankInputs,
                                       cached: Tokens) -> f64 {
    let pending = spec.prompt_tokens.saturating_sub(cached);
    let t_mat = cost.prefill_time(pending).0 as f64;
    t_mat * spec.prompt_tokens.0 as f64
        + memory_over_time_fresh(spec, predictions, handling, cost,
                                 inputs)
}

/// Shared core: decode ramps + per-API waste terms from `start_seg`
/// onward, starting at context `ctx` with `done_in_first` tokens of the
/// first segment already generated.
#[allow(clippy::too_many_arguments)]
fn segments_integral(start_seg: usize, done_in_first: u64, mut ctx: f64,
                     num_segments: usize,
                     predictions: &[SegmentPrediction],
                     handling: &[HandlingStrategy], cost: &CostModel,
                     inputs: &RankInputs) -> f64 {
    let t_iter = inputs.t_iter.0.max(1) as f64;
    let mut total = 0.0;
    for seg in start_seg..num_segments {
        let pred = &predictions[seg];
        // Remaining decode tokens in this segment.
        let done = if seg == start_seg { done_in_first } else { 0 };
        let d = pred.decode_tokens.0.saturating_sub(done) as f64;
        // Decode ramp: sum_{k=1..d} (ctx + k) * t_iter.
        total += t_iter * (d * ctx + d * (d + 1.0) / 2.0);
        ctx += d;

        if let Some(api_duration) = pred.api_duration {
            let strategy = handling[seg];
            // Expected cached recompute on a post-Discard return: the
            // full blocks of the context at the API call, registered at
            // the encounter and re-pinned by the recompute. Only a live
            // prefix cache sets `prefix_cached_block`, so with caching
            // off the term is zero and eqn (2) — hence the whole score —
            // stays byte-identical to the uncached engine.
            let cached = match inputs.prefix_cached_block {
                Some(bs) if bs > 0 => {
                    let c = ctx as u64;
                    Tokens(c / bs * bs)
                }
                _ => Tokens::ZERO,
            };
            let inp = WasteInputs {
                ctx: Tokens(ctx as u64),
                api_duration,
                c_other: inputs.c_other_est,
                cached,
            };
            total += waste_of(strategy, &inp, cost);
            ctx += pred.response_tokens.0 as f64;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::{ApiCallSpec, ApiType, HandlingStrategy,
                               RequestSpec, SegmentPrediction};
    use crate::core::types::RequestId;

    /// Unit-cost world: t_iter = 1 s, prefill 1 s/token, swap free — the
    /// Fig 3 example's regime.
    fn unit_cost() -> CostModel {
        CostModel::unit()
    }

    fn unit_inputs(c_other: u64) -> RankInputs {
        RankInputs {
            t_iter: Micros(1_000_000),
            c_other_est: Tokens(c_other),
            account_prefill: false,
            prefix_cached_block: None,
        }
    }

    fn fig3_request(id: u64, pre: u64, api_units: u64, post: u64,
                    strategy: HandlingStrategy) -> Request {
        let spec = RequestSpec {
            id: RequestId(id),
            arrival: Micros::ZERO,
            prompt: String::new(),
            prompt_tokens: Tokens(0),
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(pre),
                api_type: ApiType::Qa,
                duration: Micros(api_units * 1_000_000),
                response_tokens: Tokens(0),
            }],
            final_decode: Tokens(post),
        };
        let preds = vec![
            SegmentPrediction {
                decode_tokens: Tokens(pre),
                api_duration: Some(Micros(api_units * 1_000_000)),
                response_tokens: Tokens(0),
            },
            SegmentPrediction {
                decode_tokens: Tokens(post),
                api_duration: None,
                response_tokens: Tokens(0),
            },
        ];
        Request::new(spec, preds, vec![strategy])
    }

    /// Unit-normalized integral: decode ramps are (token x us) with
    /// t_iter = 1e6 us and waste terms are (us x token), so dividing by
    /// 1e6 yields the paper's token-unit numbers.
    fn score_units(r: &Request, c_other: u64) -> f64 {
        memory_over_time(r, &unit_cost(), &unit_inputs(c_other)) / 1e6
    }

    #[test]
    fn fig3_ordering_r3_r2_r1() {
        // Table 1: R1 (6 total, API@5, dur 2, Preserve), R2 (2, @1, 7,
        // Discard), R3 (3, @2, 1, Swap). Paper §3.1: "R3 ... should run
        // first ... followed by R2, with R1 ... scheduled last."
        let r1 = fig3_request(1, 5, 2, 1, HandlingStrategy::Preserve);
        let r2 = fig3_request(2, 1, 7, 1, HandlingStrategy::Discard);
        let r3 = fig3_request(3, 2, 1, 1, HandlingStrategy::Swap);
        // c_other estimate = budget/2 = 3 (see engine profiling init).
        let (s1, s2, s3) = (score_units(&r1, 3), score_units(&r2, 3),
                            score_units(&r3, 3));
        assert!(s3 < s2, "R3 {s3} should rank before R2 {s2}");
        assert!(s2 < s1, "R2 {s2} should rank before R1 {s1}");
    }

    #[test]
    fn fig3_exact_values() {
        // Hand-computed in the unit world with C_other = 3:
        // R1: ramp 1+2+3+4+5 = 15, preserve 5*2 = 10, post (5+1)=6 -> 31
        // R2: ramp 1, discard T_fwd(1)*(1+3) = 4, post (1+1)=2 -> 7
        // R3: ramp 1+2 = 3, swap 2*0*c = 0, post (2+1)=3 -> 6
        let r1 = fig3_request(1, 5, 2, 1, HandlingStrategy::Preserve);
        let r2 = fig3_request(2, 1, 7, 1, HandlingStrategy::Discard);
        let r3 = fig3_request(3, 2, 1, 1, HandlingStrategy::Swap);
        assert!((score_units(&r1, 3) - 31.0).abs() < 1e-9);
        assert!((score_units(&r2, 3) - 7.0).abs() < 1e-9);
        assert!((score_units(&r3, 3) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn progress_reduces_score() {
        let mut r = fig3_request(1, 5, 2, 1, HandlingStrategy::Preserve);
        let before = score_units(&r, 0);
        r.segment_generated = Tokens(3);
        r.logical_context = Tokens(3);
        let after = score_units(&r, 0);
        assert!(after < before);
    }

    #[test]
    fn completed_api_drops_waste_term() {
        let mut r = fig3_request(1, 5, 20, 1, HandlingStrategy::Preserve);
        let before = score_units(&r, 0);
        // Move to final segment (API done).
        r.segment = 1;
        r.segment_generated = Tokens(0);
        r.logical_context = Tokens(5);
        let after = score_units(&r, 0);
        // before includes preserve waste 5*20 = 100; after only the final
        // decode ramp (5+1) = 6.
        assert!((after - 6.0).abs() < 1e-9, "after {after}");
        assert!(before > 100.0);
    }

    #[test]
    fn longer_api_means_lower_priority_under_preserve() {
        let short = fig3_request(1, 5, 2, 1, HandlingStrategy::Preserve);
        let long = fig3_request(2, 5, 50, 1, HandlingStrategy::Preserve);
        assert!(score_units(&short, 0) < score_units(&long, 0));
    }

    #[test]
    fn partial_prefill_hold_term_only_when_enabled() {
        // A half-materialized request (chunked-prefill state): 4 of 8
        // context tokens live, 4 still owed.
        let mut r = fig3_request(1, 5, 2, 1, HandlingStrategy::Preserve);
        r.logical_context = Tokens(8);
        r.context = Tokens(4);
        r.pending_materialize = Tokens(4);
        let off = memory_over_time(&r, &unit_cost(), &unit_inputs(3));
        let on = memory_over_time(&r, &unit_cost(), &RankInputs {
            account_prefill: true,
            ..unit_inputs(3)
        });
        // Unit cost: 4 tokens x 1 s/token prefill x 4 held tokens.
        assert!((on - off - 4.0 * 1e6 * 4.0).abs() < 1e-6,
                "off {off} on {on}");
        // Legacy states (nothing pending, or nothing yet live) are
        // unaffected even when enabled.
        r.pending_materialize = Tokens::ZERO;
        let a = memory_over_time(&r, &unit_cost(), &unit_inputs(3));
        let b = memory_over_time(&r, &unit_cost(), &RankInputs {
            account_prefill: true,
            ..unit_inputs(3)
        });
        assert_eq!(a, b);
    }

    #[test]
    fn fresh_integral_matches_request_integral() {
        // The spec-level probe entry point must agree exactly with the
        // full request scorer for a not-yet-started request.
        for strategy in [HandlingStrategy::Preserve,
                         HandlingStrategy::Discard,
                         HandlingStrategy::Swap] {
            let r = fig3_request(2, 1, 7, 1, strategy);
            let fresh = memory_over_time_fresh(
                &r.spec, &r.predictions, &r.handling, &unit_cost(),
                &unit_inputs(3));
            assert_eq!(fresh,
                       memory_over_time(&r, &unit_cost(),
                                        &unit_inputs(3)));
        }
    }

    #[test]
    fn cached_block_discount_in_fig3_unit_world() {
        // R2 from Fig 3 (Discard at ctx 1): with block size 1 the whole
        // context at the API call is expected cached, so the discard
        // waste term T_fwd(1)*(1+3) = 4 vanishes: 7 -> 3. R1 (Preserve)
        // is never discounted; R3 (Swap) is discounted only in its
        // transfer term, which is zero in the unit-cost world — both
        // keep their Fig 3 scores.
        let r2 = fig3_request(2, 1, 7, 1, HandlingStrategy::Discard);
        let discounted = RankInputs {
            prefix_cached_block: Some(1),
            ..unit_inputs(3)
        };
        let off = memory_over_time(&r2, &unit_cost(), &unit_inputs(3));
        let on = memory_over_time(&r2, &unit_cost(), &discounted);
        assert!((off / 1e6 - 7.0).abs() < 1e-9, "off {off}");
        assert!((on / 1e6 - 3.0).abs() < 1e-9, "on {on}");

        let r1 = fig3_request(1, 5, 2, 1, HandlingStrategy::Preserve);
        let r3 = fig3_request(3, 2, 1, 1, HandlingStrategy::Swap);
        for r in [&r1, &r3] {
            assert_eq!(memory_over_time(r, &unit_cost(), &unit_inputs(3)),
                       memory_over_time(r, &unit_cost(), &discounted));
        }

        // A coarser block (4 tokens) covers no full block of ctx 1:
        // nothing is expected cached and the score is unchanged.
        let coarse = RankInputs {
            prefix_cached_block: Some(4),
            ..unit_inputs(3)
        };
        assert_eq!(memory_over_time(&r2, &unit_cost(), &unit_inputs(3)),
                   memory_over_time(&r2, &unit_cost(), &coarse));
    }

    #[test]
    fn prefixed_fresh_integral_discounts_prefill_leg_only() {
        // Unit world: prefill is 1 s/token, so a 6-token prompt's
        // uncached prefill leg holds 6 tokens for 6 s = 36 token-units
        // on top of the plain fresh integral; 4 cached tokens shrink the
        // leg to 2 s x 6 = 12; a fully cached prompt drops it entirely.
        let mut r = fig3_request(2, 1, 7, 1, HandlingStrategy::Discard);
        r.spec.prompt_tokens = Tokens(6);
        let base = memory_over_time_fresh(&r.spec, &r.predictions,
                                          &r.handling, &unit_cost(),
                                          &unit_inputs(3));
        let leg = |cached: u64| {
            memory_over_time_fresh_prefixed(&r.spec, &r.predictions,
                                            &r.handling, &unit_cost(),
                                            &unit_inputs(3),
                                            Tokens(cached))
                - base
        };
        assert!((leg(0) - 36.0 * 1e6).abs() < 1e-3, "uncached {}", leg(0));
        assert!((leg(4) - 12.0 * 1e6).abs() < 1e-3, "partial {}", leg(4));
        assert_eq!(leg(6), 0.0, "fully cached prompt skips the leg");
        // Over-credit (stale index optimism) saturates, never negative.
        assert_eq!(leg(99), 0.0);
        // More cached tokens never rank a replica worse.
        assert!(leg(4) < leg(1));
    }

    #[test]
    fn same_length_different_strategy_ranks_differently() {
        // Paper §3.2.2: "it may order two requests with the same total
        // length differently because they have different handling
        // strategies during the API call."
        let p = fig3_request(1, 5, 10, 1, HandlingStrategy::Preserve);
        let d = fig3_request(2, 5, 10, 1, HandlingStrategy::Discard);
        assert_ne!(score_units(&p, 3), score_units(&d, 3));
    }
}
