//! LAMPS's rank function: the **memory-over-time integral** (paper §4.3,
//! Fig 4) of a request's remaining predicted lifetime, including the waste
//! terms of its assigned API handling strategies.
//!
//! > "Our insight is that evaluating memory usage by integrating the
//! > memory-over-time function offers a more accurate measure of resource
//! > consumption than relying on instantaneous memory values." (§4.2)
//!
//! Units: token-microseconds. Decode phases contribute a ramp
//! `sum_{k=1..d} (ctx + k) * t_iter`; each API call contributes its waste
//! equation value (eqns (1)-(3), `handling.rs`) for the strategy assigned
//! to it. Lower integral -> scheduled earlier.

use crate::config::CostModel;
use crate::coordinator::handling::{waste_of, WasteInputs};
use crate::core::request::Request;
use crate::core::types::{Micros, Tokens};

/// Live quantities the score depends on (profiled by the engine).
#[derive(Debug, Clone, Copy)]
pub struct RankInputs {
    /// Current estimate of one decode iteration's duration.
    pub t_iter: Micros,
    /// Profiled average co-batched context, the `C_other` estimate
    /// (§3.2.1 "This estimation involves profiling the number of requests
    /// in a batch").
    pub c_other_est: Tokens,
    /// Chunked prefill enabled: charge the held context of
    /// partially-materialized requests for their remaining prefill time.
    /// Off (the legacy engine), that state never exists and the integral
    /// is bit-identical to the original formula.
    pub account_prefill: bool,
}

/// Memory-over-time integral of the *remaining* predicted lifetime of `r`.
pub fn memory_over_time(r: &Request, cost: &CostModel,
                        inputs: &RankInputs) -> f64 {
    let t_iter = inputs.t_iter.0.max(1) as f64;
    let mut total = 0.0;
    let mut ctx = r.logical_context.0 as f64;

    // Chunked prefill can pause a request mid-materialization (context
    // partially live, `pending_materialize` still owed). The live part
    // sits in device memory for the remaining prefill time before the
    // decode ramp below even starts — charge it, or half-prefilled
    // giants rank as if their held KV were free.
    if inputs.account_prefill
        && r.pending_materialize > Tokens::ZERO
        && r.context > Tokens::ZERO
    {
        let t_mat = cost.prefill_time(r.pending_materialize).0 as f64;
        total += t_mat * r.context.0 as f64;
    }

    for seg in r.segment..r.spec.num_segments() {
        let pred = &r.predictions[seg];
        // Remaining decode tokens in this segment.
        let done = if seg == r.segment {
            r.segment_generated.0
        } else {
            0
        };
        let d = pred.decode_tokens.0.saturating_sub(done) as f64;
        // Decode ramp: sum_{k=1..d} (ctx + k) * t_iter.
        total += t_iter * (d * ctx + d * (d + 1.0) / 2.0);
        ctx += d;

        if let Some(api_duration) = pred.api_duration {
            let strategy = r.handling[seg];
            // `cached` stays zero here: the rank integral is computed
            // at admission, before any of this request's blocks exist
            // in the prefix cache, and scores must stay byte-identical
            // with the cache disabled. (Discount follow-on tracked in
            // ROADMAP.)
            let inp = WasteInputs {
                ctx: Tokens(ctx as u64),
                api_duration,
                c_other: inputs.c_other_est,
                cached: Tokens::ZERO,
            };
            total += waste_of(strategy, &inp, cost);
            ctx += pred.response_tokens.0 as f64;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::{ApiCallSpec, ApiType, HandlingStrategy,
                               RequestSpec, SegmentPrediction};
    use crate::core::types::RequestId;

    /// Unit-cost world: t_iter = 1 s, prefill 1 s/token, swap free — the
    /// Fig 3 example's regime.
    fn unit_cost() -> CostModel {
        CostModel::unit()
    }

    fn unit_inputs(c_other: u64) -> RankInputs {
        RankInputs {
            t_iter: Micros(1_000_000),
            c_other_est: Tokens(c_other),
            account_prefill: false,
        }
    }

    fn fig3_request(id: u64, pre: u64, api_units: u64, post: u64,
                    strategy: HandlingStrategy) -> Request {
        let spec = RequestSpec {
            id: RequestId(id),
            arrival: Micros::ZERO,
            prompt: String::new(),
            prompt_tokens: Tokens(0),
            api_calls: vec![ApiCallSpec {
                decode_before: Tokens(pre),
                api_type: ApiType::Qa,
                duration: Micros(api_units * 1_000_000),
                response_tokens: Tokens(0),
            }],
            final_decode: Tokens(post),
        };
        let preds = vec![
            SegmentPrediction {
                decode_tokens: Tokens(pre),
                api_duration: Some(Micros(api_units * 1_000_000)),
                response_tokens: Tokens(0),
            },
            SegmentPrediction {
                decode_tokens: Tokens(post),
                api_duration: None,
                response_tokens: Tokens(0),
            },
        ];
        Request::new(spec, preds, vec![strategy])
    }

    /// Unit-normalized integral: decode ramps are (token x us) with
    /// t_iter = 1e6 us and waste terms are (us x token), so dividing by
    /// 1e6 yields the paper's token-unit numbers.
    fn score_units(r: &Request, c_other: u64) -> f64 {
        memory_over_time(r, &unit_cost(), &unit_inputs(c_other)) / 1e6
    }

    #[test]
    fn fig3_ordering_r3_r2_r1() {
        // Table 1: R1 (6 total, API@5, dur 2, Preserve), R2 (2, @1, 7,
        // Discard), R3 (3, @2, 1, Swap). Paper §3.1: "R3 ... should run
        // first ... followed by R2, with R1 ... scheduled last."
        let r1 = fig3_request(1, 5, 2, 1, HandlingStrategy::Preserve);
        let r2 = fig3_request(2, 1, 7, 1, HandlingStrategy::Discard);
        let r3 = fig3_request(3, 2, 1, 1, HandlingStrategy::Swap);
        // c_other estimate = budget/2 = 3 (see engine profiling init).
        let (s1, s2, s3) = (score_units(&r1, 3), score_units(&r2, 3),
                            score_units(&r3, 3));
        assert!(s3 < s2, "R3 {s3} should rank before R2 {s2}");
        assert!(s2 < s1, "R2 {s2} should rank before R1 {s1}");
    }

    #[test]
    fn fig3_exact_values() {
        // Hand-computed in the unit world with C_other = 3:
        // R1: ramp 1+2+3+4+5 = 15, preserve 5*2 = 10, post (5+1)=6 -> 31
        // R2: ramp 1, discard T_fwd(1)*(1+3) = 4, post (1+1)=2 -> 7
        // R3: ramp 1+2 = 3, swap 2*0*c = 0, post (2+1)=3 -> 6
        let r1 = fig3_request(1, 5, 2, 1, HandlingStrategy::Preserve);
        let r2 = fig3_request(2, 1, 7, 1, HandlingStrategy::Discard);
        let r3 = fig3_request(3, 2, 1, 1, HandlingStrategy::Swap);
        assert!((score_units(&r1, 3) - 31.0).abs() < 1e-9);
        assert!((score_units(&r2, 3) - 7.0).abs() < 1e-9);
        assert!((score_units(&r3, 3) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn progress_reduces_score() {
        let mut r = fig3_request(1, 5, 2, 1, HandlingStrategy::Preserve);
        let before = score_units(&r, 0);
        r.segment_generated = Tokens(3);
        r.logical_context = Tokens(3);
        let after = score_units(&r, 0);
        assert!(after < before);
    }

    #[test]
    fn completed_api_drops_waste_term() {
        let mut r = fig3_request(1, 5, 20, 1, HandlingStrategy::Preserve);
        let before = score_units(&r, 0);
        // Move to final segment (API done).
        r.segment = 1;
        r.segment_generated = Tokens(0);
        r.logical_context = Tokens(5);
        let after = score_units(&r, 0);
        // before includes preserve waste 5*20 = 100; after only the final
        // decode ramp (5+1) = 6.
        assert!((after - 6.0).abs() < 1e-9, "after {after}");
        assert!(before > 100.0);
    }

    #[test]
    fn longer_api_means_lower_priority_under_preserve() {
        let short = fig3_request(1, 5, 2, 1, HandlingStrategy::Preserve);
        let long = fig3_request(2, 5, 50, 1, HandlingStrategy::Preserve);
        assert!(score_units(&short, 0) < score_units(&long, 0));
    }

    #[test]
    fn partial_prefill_hold_term_only_when_enabled() {
        // A half-materialized request (chunked-prefill state): 4 of 8
        // context tokens live, 4 still owed.
        let mut r = fig3_request(1, 5, 2, 1, HandlingStrategy::Preserve);
        r.logical_context = Tokens(8);
        r.context = Tokens(4);
        r.pending_materialize = Tokens(4);
        let off = memory_over_time(&r, &unit_cost(), &unit_inputs(3));
        let on = memory_over_time(&r, &unit_cost(), &RankInputs {
            account_prefill: true,
            ..unit_inputs(3)
        });
        // Unit cost: 4 tokens x 1 s/token prefill x 4 held tokens.
        assert!((on - off - 4.0 * 1e6 * 4.0).abs() < 1e-6,
                "off {off} on {on}");
        // Legacy states (nothing pending, or nothing yet live) are
        // unaffected even when enabled.
        r.pending_materialize = Tokens::ZERO;
        let a = memory_over_time(&r, &unit_cost(), &unit_inputs(3));
        let b = memory_over_time(&r, &unit_cost(), &RankInputs {
            account_prefill: true,
            ..unit_inputs(3)
        });
        assert_eq!(a, b);
    }

    #[test]
    fn same_length_different_strategy_ranks_differently() {
        // Paper §3.2.2: "it may order two requests with the same total
        // length differently because they have different handling
        // strategies during the API call."
        let p = fig3_request(1, 5, 10, 1, HandlingStrategy::Preserve);
        let d = fig3_request(2, 5, 10, 1, HandlingStrategy::Discard);
        assert_ne!(score_units(&p, 3), score_units(&d, 3));
    }
}
