//! # LAMPS — LLM API- and Memory-based Predictive Scheduling
//!
//! Production-quality reproduction of *Fast Inference for Augmented Large
//! Language Models* (Shahout et al., 2024) as a three-layer
//! Rust + JAX + Pallas serving stack.
//!
//! The paper's contribution — a unified scheduler for API-augmented LLM
//! requests that (1) predicts pre-API output length and API duration,
//! (2) assigns the memory-handling strategy (Preserve / Discard / Swap)
//! minimizing memory waste *before* the request runs, and (3) ranks requests
//! by their **memory-over-time integral** — lives in [`coordinator`].
//! [`cluster`] scales it out: a `ReplicaSet` dispatches requests across
//! N engine replicas, with the same memory-over-time integral steering
//! cross-replica placement.
//!
//! Layer map (see `DESIGN.md`):
//! - **L3 (this crate)**: scheduler, batcher, KV-cache manager, API
//!   executor, baselines, workloads, metrics, CLI, serving frontend.
//! - **L2/L1 (build-time Python)**: TinyGPT JAX model + Pallas attention
//!   kernels, AOT-lowered to `artifacts/*.hlo.txt`.
//! - **Runtime**: [`runtime`] loads the HLO artifacts via the PJRT C API
//!   (`xla` crate) and executes them on the request path — Python is never
//!   invoked at serving time.
//!
//! Quick start (simulated backend):
//! ```no_run
//! use lamps::config::SystemConfig;
//! use lamps::engine::Engine;
//! use lamps::workload::{infercept, ArrivalProcess};
//!
//! let cfg = SystemConfig::default();
//! let trace = infercept::single_api_dataset(100, 2.0, 42);
//! let mut engine = Engine::simulated(cfg);
//! let report = engine.run_trace(&trace);
//! println!("mean latency: {:.3}s", report.latency.mean_secs());
//! ```
//!
//! ## Correctness tooling
//!
//! Scheduling quality here degrades *silently* when memory accounting
//! or determinism slips (wrong ranks, not crashes), so the invariants
//! the paper relies on are machine-checked at two layers:
//!
//! - **Static** — [`lint`] + the `lamps-lint` binary enforce the
//!   project rules distilled from PR 1–6 reviews: no string-spliced
//!   JSON on the wire (`wire-format`), no `.unwrap()`/`panic!`/
//!   slice-indexing in scheduler-critical dirs without a
//!   `// lamps-lint: allow(<rule>) <reason>` escape (`panic`), no
//!   wall-clock reads outside `engine/clock.rs` (`wall-clock`), no
//!   f64 accumulation over `HashMap` iteration order (`float-iter`),
//!   read-only placement probes (`probe-purity`), and no allocating
//!   `util::json` calls on the serving hot path now that [`wire`]
//!   owns frame encode/decode (`wire-hot-path`). CI runs
//!   `cargo run --bin lamps-lint` as a gate.
//! - **Runtime** — [`audit`] re-derives the block-conservation,
//!   prefix-refcount, shared-index-subset, queue-order, clock- and
//!   event-causality invariants after every engine/fleet step.
//!   Enabled with `--audit` (or `LAMPS_AUDIT=on` for the benches),
//!   always on under `cfg(debug_assertions)`, and observe-only: the
//!   run report is byte-identical with the auditor on or off.

pub mod audit;
pub mod bench;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod engine;
pub mod kv;
pub mod lint;
pub mod metrics;
pub mod predictor;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod server;
pub mod util;
pub mod wire;
pub mod workload;

pub use config::SystemConfig;
pub use core::request::{Request, RequestSpec};
pub use core::types::{Micros, RequestId, Tokens};
